//! SCATTER command-line interface.
//!
//! ```text
//! scatter bench <table1|table2|table3|fig4|fig5|fig6|fig8|fig9|fig10|engine|all>
//!         [--samples N] [--models cnn3,vgg8,resnet18] [--threads 1,2,4,8]
//! scatter config [--preset default|dense|foundry] [--out FILE]
//! scatter gamma  [--heatsim]
//! scatter info
//! ```
//!
//! `bench engine` sweeps the sparsity-compiled execution engine across
//! worker-thread counts × structured column sparsity and writes
//! `BENCH_engine.json` at the repo root.
//!
//! (Hand-rolled parsing: the offline toolchain has no clap.)

use scatter::bench::{self, BenchCtx};
use scatter::config::AcceleratorConfig;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "bench" => cmd_bench(&args[1..]),
        "config" => cmd_config(&args[1..]),
        "gamma" => cmd_gamma(&args[1..]),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "usage: scatter <bench|config|gamma|info> [...]\n\
                 \n\
                 bench <table1|table2|table3|fig4|fig5|fig6|fig8|fig9|fig10|engine|all>\n\
                 \x20      [--samples N] [--models cnn3,vgg8,resnet18] [--threads 1,2,4,8]\n\
                 config [--preset default|dense|foundry] [--out FILE]\n\
                 gamma  [--heatsim]\n\
                 info"
            );
            std::process::exit(if cmd == "help" { 0 } else { 2 });
        }
    }
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(String::as_str)
}

fn cmd_bench(args: &[String]) {
    let which = args.first().map(String::as_str).unwrap_or("all");
    let samples: usize =
        flag_value(args, "--samples").and_then(|s| s.parse().ok()).unwrap_or(100);
    let ctx = BenchCtx::new(samples);
    match which {
        "table1" => println!("{}", bench::table1::run(&ctx)),
        "table2" => println!("{}", bench::table2::run(&ctx)),
        "table3" => {
            let models = flag_value(args, "--models").unwrap_or("cnn3,vgg8,resnet18");
            let workloads: Vec<_> = models
                .split(',')
                .filter_map(|m| match m.trim() {
                    "cnn3" => Some(bench::common::Workload::Cnn3),
                    "vgg8" => Some(bench::common::Workload::Vgg8),
                    "resnet18" => Some(bench::common::Workload::Resnet18),
                    _ => None,
                })
                .collect();
            println!("{}", bench::table3::run_models(&ctx, &workloads));
        }
        "fig4" => println!("{}", bench::fig4::run(&ctx)),
        "fig5" => println!("{}", bench::fig5::run(&ctx)),
        "fig6" => println!("{}", bench::fig6::run(&ctx)),
        "fig8" => println!("{}", bench::fig8::run(&ctx)),
        "fig9" => {
            println!("{}", bench::fig9::run_a(&ctx));
            println!("{}", bench::fig9::run_b(&ctx));
        }
        "fig10" => println!("{}", bench::fig10::run(&ctx)),
        "engine" => {
            let threads: Vec<usize> = flag_value(args, "--threads")
                .unwrap_or("1,2,4,8")
                .split(',')
                .filter_map(|t| t.trim().parse().ok())
                .collect();
            // --samples doubles as the per-cell time budget (ms × 10):
            // the default 100 gives ~1 s per cell
            let budget = std::time::Duration::from_millis((samples as u64) * 10);
            println!("{}", bench::engine::run(&threads, budget));
        }
        "all" => bench::run_all(&ctx),
        other => {
            eprintln!("unknown bench target '{other}'");
            std::process::exit(2);
        }
    }
}

fn cmd_config(args: &[String]) {
    let cfg = match flag_value(args, "--preset").unwrap_or("default") {
        "dense" => AcceleratorConfig::dense_optimal(),
        "foundry" => AcceleratorConfig::foundry_baseline(),
        _ => AcceleratorConfig::default(),
    };
    let json = cfg.to_json();
    match flag_value(args, "--out") {
        Some(path) => {
            std::fs::write(path, &json).expect("write config");
            eprintln!("wrote {path}");
        }
        None => println!("{json}"),
    }
}

fn cmd_gamma(args: &[String]) {
    use scatter::thermal::GammaModel;
    if args.iter().any(|a| a == "--heatsim") {
        let (samples, model) = scatter::thermal::heatsim::characterize(
            &scatter::thermal::heatsim::HeatSimConfig::default(),
            23.0,
        );
        println!("# heat-solver gamma(d) samples and piecewise refit");
        println!("# d_um  gamma_sample  gamma_fit");
        for (d, g) in samples {
            println!("{d:6.1}  {g:.6}  {:.6}", model.eval(d));
        }
    } else {
        let g = GammaModel::paper();
        println!("# paper Eq.-10 gamma(d)");
        for (d, v) in g.sample(60.0, 1.0) {
            println!("{d:6.1}  {v:.6}");
        }
    }
}

fn cmd_info() {
    let cfg = AcceleratorConfig::default();
    let area = scatter::area::AreaModel::with_defaults(cfg.clone());
    let power = scatter::power::PowerModel::with_defaults(cfg.clone());
    println!("SCATTER digital twin");
    println!("  default config: R={} C={} k1={} k2={} r={} c={} f={} GHz",
        cfg.tiles_r, cfg.cores_c, cfg.k1, cfg.k2, cfg.share_r, cfg.share_c, cfg.freq_ghz);
    println!("  chip area     : {:.2} mm^2", area.total_mm2());
    println!("  dense power   : {:.2} W (closed form)", power.dense(None).total_w());
    match scatter::runtime::ArtifactRuntime::new("artifacts") {
        Ok(rt) => println!("  PJRT platform : {}", rt.platform()),
        Err(e) => println!("  PJRT platform : unavailable ({e})"),
    }
}

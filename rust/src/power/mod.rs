//! On-chip power modeling (§3.2.1, Eqs. 2–4) with sparsity-aware gating
//! (§3.3.2–3.3.3) and energy accounting (§4.1 metrics).

pub mod energy;
pub mod model;

pub use energy::{EnergyAccumulator, EnergyReport};
pub use model::{PowerBreakdown, PowerModel};

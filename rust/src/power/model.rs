//! The analytic on-chip power model (Eqs. 2–4):
//!
//! ```text
//!   P_in  = (R·C·k2 / r) · (P_mod + P_eDAC(b_in, f))            (Eq. 2)
//!   P_wgt = R·C·k1·k2 · (P_MZI + 2·P_PD)                        (Eq. 3)
//!   P_out = (R·C·k1 / c) · (P_TIA + P_ADC(b_o, f))              (Eq. 4)
//! ```
//!
//! Sparsity changes each term through gating:
//! * **IG** removes DAC+MZM power on pruned weight-chunk columns;
//! * weight-MZI power is computed from the *actual deployed phases*
//!   (pruned MZIs hold Δφ = 0 and cost nothing);
//! * **OG** removes TIA+ADC power on pruned weight-chunk rows;
//! * **LR** adds the rerouter's splitter-tree hold power (computed by
//!   `crate::rerouter` from the column mask).
//!
//! Off-chip laser and low-speed weight DACs are excluded (paper note).

use crate::config::{AcceleratorConfig, DacKind};
use crate::devices::{Adc, Dac, DeviceLibrary, EoDac, Mzi, MziSpec, Mzm, Tia};
use crate::thermal::gamma::GammaModel;

/// Itemized power numbers, all in mW.
#[derive(Debug, Clone, Copy, Default)]
pub struct PowerBreakdown {
    pub input_dac_mw: f64,
    pub input_mod_mw: f64,
    pub weight_mzi_mw: f64,
    pub weight_pd_mw: f64,
    pub readout_tia_mw: f64,
    pub readout_adc_mw: f64,
    pub rerouter_mw: f64,
}

impl PowerBreakdown {
    pub fn input_mw(&self) -> f64 {
        self.input_dac_mw + self.input_mod_mw
    }
    pub fn weight_mw(&self) -> f64 {
        self.weight_mzi_mw + self.weight_pd_mw
    }
    pub fn readout_mw(&self) -> f64 {
        self.readout_tia_mw + self.readout_adc_mw
    }
    pub fn total_mw(&self) -> f64 {
        self.input_mw() + self.weight_mw() + self.readout_mw() + self.rerouter_mw
    }
    pub fn total_w(&self) -> f64 {
        self.total_mw() / 1e3
    }

    pub fn add(&mut self, other: &PowerBreakdown) {
        self.input_dac_mw += other.input_dac_mw;
        self.input_mod_mw += other.input_mod_mw;
        self.weight_mzi_mw += other.weight_mzi_mw;
        self.weight_pd_mw += other.weight_pd_mw;
        self.readout_tia_mw += other.readout_tia_mw;
        self.readout_adc_mw += other.readout_adc_mw;
        self.rerouter_mw += other.rerouter_mw;
    }

    pub fn scaled(&self, f: f64) -> PowerBreakdown {
        PowerBreakdown {
            input_dac_mw: self.input_dac_mw * f,
            input_mod_mw: self.input_mod_mw * f,
            weight_mzi_mw: self.weight_mzi_mw * f,
            weight_pd_mw: self.weight_pd_mw * f,
            readout_tia_mw: self.readout_tia_mw * f,
            readout_adc_mw: self.readout_adc_mw * f,
            rerouter_mw: self.rerouter_mw * f,
        }
    }
}

/// Power model bound to a configuration + device library.
#[derive(Debug, Clone)]
pub struct PowerModel {
    pub cfg: AcceleratorConfig,
    pub lib: DeviceLibrary,
    mzi: Mzi,
}

impl PowerModel {
    pub fn new(cfg: AcceleratorConfig, lib: DeviceLibrary, gamma: &GammaModel) -> Self {
        let mzi = Mzi::new(MziSpec::from_kind(cfg.mzi), cfg.l_s, gamma);
        Self { cfg, lib, mzi }
    }

    pub fn with_defaults(cfg: AcceleratorConfig) -> Self {
        Self::new(cfg, DeviceLibrary::default(), &GammaModel::paper())
    }

    /// The weight-array MZI device this model uses.
    pub fn mzi(&self) -> &Mzi {
        &self.mzi
    }

    /// Per-port input DAC power (mW) under the configured DAC kind.
    pub fn dac_power_mw(&self) -> f64 {
        match self.cfg.dac {
            DacKind::Edac => Dac::new(self.cfg.b_in, self.cfg.freq_ghz, self.lib.edac_p0_pj)
                .power_mw(),
            DacKind::Eodac { segments, bits_per_seg } => {
                EoDac::new(segments, bits_per_seg, self.cfg.freq_ghz, self.lib.edac_p0_pj)
                    .power_mw()
            }
        }
    }

    /// Per-port modulator power (mW), Eq. 2.
    pub fn mzm_power_mw(&self) -> f64 {
        Mzm::new(
            self.lib.mzm_static_mw,
            self.lib.mzm_energy_pj,
            self.cfg.freq_ghz,
            self.lib.leakage_floor(),
        )
        .power_mw()
    }

    /// Per-channel readout power (mW), Eq. 4 inner term.
    pub fn readout_channel_mw(&self) -> f64 {
        Tia::new(self.lib.tia_mw).power_mw
            + Adc::new(self.cfg.b_o, self.cfg.freq_ghz, self.lib.adc_p0_pj).power_mw()
    }

    /// Dense-case power with an *average* per-MZI phase magnitude
    /// (closed-form; used by design-space sweeps where no concrete weights
    /// exist yet). `mean_abs_phase` defaults to the uniform-weight value.
    pub fn dense(&self, mean_abs_phase: Option<f64>) -> PowerBreakdown {
        let c = &self.cfg;
        let n_in = (c.n_cores() * c.k2) as f64 / c.share_r as f64;
        let n_wgt = (c.n_cores() * c.k1 * c.k2) as f64;
        let n_out = (c.n_cores() * c.k1) as f64 / c.share_c as f64;
        let p_mzi = match mean_abs_phase {
            Some(phi) => self.mzi.power_mw(phi),
            None => self.mzi.mean_power_uniform_mw(),
        };
        PowerBreakdown {
            input_dac_mw: n_in * self.dac_power_mw(),
            input_mod_mw: n_in * self.mzm_power_mw(),
            weight_mzi_mw: n_wgt * p_mzi,
            weight_pd_mw: n_wgt * 2.0 * self.lib.pd_mw,
            readout_tia_mw: n_out * Tia::new(self.lib.tia_mw).power_mw,
            readout_adc_mw: n_out
                * Adc::new(c.b_o, c.freq_ghz, self.lib.adc_p0_pj).power_mw(),
            rerouter_mw: 0.0,
        }
    }

    /// Power for one deployed weight chunk given the concrete phases and
    /// structured masks.
    ///
    /// * `phases` — row-major `rk1 × ck2` programmed phase magnitudes (the
    ///   chunk mapped across r·c PTCs); pruned entries must already be 0.
    /// * `col_mask[ck2]` — weight-chunk *column* mask (input ports);
    ///   `false` ⇒ pruned ⇒ DAC/MZM gated when IG is on.
    /// * `row_mask[rk1]` — weight-chunk *row* mask (output channels);
    ///   `false` ⇒ pruned ⇒ TIA/ADC gated when OG is on.
    /// * `rerouter_mw` — hold power of the LR splitter trees for this mask
    ///   (0 when LR is off), from `crate::rerouter`.
    ///
    /// Numbers are for **one chunk slot** (r·c PTCs + its shared input
    /// module and readout bank). Whole-accelerator power at full occupancy
    /// is the sum over the `R·C/(r·c)` slots (see `coordinator::engine`).
    pub fn chunk(
        &self,
        phases: &[f64],
        col_mask: &[bool],
        row_mask: &[bool],
        rerouter_mw: f64,
    ) -> PowerBreakdown {
        let c = &self.cfg;
        let (rows, cols) = c.chunk_shape();
        assert_eq!(phases.len(), rows * cols, "phase chunk shape mismatch");
        assert_eq!(col_mask.len(), cols, "col mask len");
        assert_eq!(row_mask.len(), rows, "row mask len");

        // --- input side: one DAC+MZM per chunk column (shared across r) ---
        let active_cols = if c.features.input_gating {
            col_mask.iter().filter(|&&m| m).count() as f64
        } else {
            cols as f64
        };
        let p_in_port = self.dac_power_mw() + self.mzm_power_mw();

        // --- weight array: actual per-MZI hold power -------------------
        let mut p_mzi_total = 0.0;
        for (ri, row) in phases.chunks(cols).enumerate() {
            for (ci, &phi) in row.iter().enumerate() {
                if !row_mask[ri] || !col_mask[ci] {
                    continue; // power-gated weight MZI
                }
                p_mzi_total += self.mzi.power_mw(phi);
            }
        }
        // PDs stay biased on active rows only when OG is enabled.
        let active_rows = if c.features.output_gating {
            row_mask.iter().filter(|&&m| m).count() as f64
        } else {
            rows as f64
        };
        let pd_count = active_rows * cols as f64;

        PowerBreakdown {
            input_dac_mw: active_cols * self.dac_power_mw(),
            input_mod_mw: active_cols * (p_in_port - self.dac_power_mw()),
            weight_mzi_mw: p_mzi_total,
            weight_pd_mw: pd_count * 2.0 * self.lib.pd_mw,
            readout_tia_mw: active_rows * Tia::new(self.lib.tia_mw).power_mw,
            readout_adc_mw: active_rows
                * Adc::new(c.b_o, c.freq_ghz, self.lib.adc_p0_pj).power_mw(),
            rerouter_mw: if c.features.light_redistribution { rerouter_mw } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparsitySupport;

    fn model(features: SparsitySupport, share: usize) -> PowerModel {
        let cfg = AcceleratorConfig {
            features,
            share_r: share,
            share_c: share,
            dac: DacKind::Edac,
            l_g: 5.0,
            ..Default::default()
        };
        PowerModel::with_defaults(cfg)
    }

    #[test]
    fn dense_breakdown_matches_eq2_4_counts() {
        let m = model(SparsitySupport::NONE, 1);
        let p = m.dense(None);
        // R*C*k2/r = 256 input ports
        let dac = Dac::new(6, 5.0, m.lib.edac_p0_pj).power_mw();
        assert!((p.input_dac_mw - 256.0 * dac).abs() < 1e-9);
        // R*C*k1/c = 256 readout channels at 12 mW ADC each
        assert!((p.readout_adc_mw - 256.0 * 12.0).abs() < 1e-6);
        // 4096 weight MZIs
        assert!(p.weight_mzi_mw > 0.0);
        assert!(p.total_w() > 1.0 && p.total_w() < 100.0);
    }

    #[test]
    fn sharing_divides_converter_power() {
        let m1 = model(SparsitySupport::NONE, 1);
        let m4 = model(SparsitySupport::NONE, 4);
        let p1 = m1.dense(None);
        let p4 = m4.dense(None);
        assert!((p1.input_dac_mw / p4.input_dac_mw - 4.0).abs() < 1e-9);
        assert!((p1.readout_adc_mw / p4.readout_adc_mw - 4.0).abs() < 1e-9);
        // weight power unchanged
        assert!((p1.weight_mzi_mw - p4.weight_mzi_mw).abs() < 1e-9);
    }

    #[test]
    fn chunk_gating_saves_power() {
        let m_full = model(SparsitySupport::FULL, 4);
        let m_none = model(SparsitySupport::NONE, 4);
        let (rows, cols) = m_full.cfg.chunk_shape();
        // half the columns and half the rows pruned
        let col_mask: Vec<bool> = (0..cols).map(|i| i % 2 == 0).collect();
        let row_mask: Vec<bool> = (0..rows).map(|i| i % 2 == 0).collect();
        let mut phases = vec![0.5; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                if !row_mask[r] || !col_mask[c] {
                    phases[r * cols + c] = 0.0;
                }
            }
        }
        let p_gated = m_full.chunk(&phases, &col_mask, &row_mask, 0.0);
        let p_ungated = m_none.chunk(&phases, &col_mask, &row_mask, 0.0);
        // same MZI power (pruned phases are 0 either way)...
        assert!((p_gated.weight_mzi_mw - p_ungated.weight_mzi_mw).abs() < 1e-9);
        // ...but gated converters halve input and readout power
        assert!((p_ungated.input_dac_mw / p_gated.input_dac_mw - 2.0).abs() < 1e-9);
        assert!((p_ungated.readout_adc_mw / p_gated.readout_adc_mw - 2.0).abs() < 1e-9);
        // and PD bias on gated rows is removed
        assert!(p_gated.weight_pd_mw < p_ungated.weight_pd_mw);
        assert!(p_gated.total_mw() < p_ungated.total_mw());
    }

    #[test]
    fn eodac_cuts_input_dac_power_2p28x() {
        let mut cfg = AcceleratorConfig { dac: DacKind::Edac, ..Default::default() };
        cfg.features = SparsitySupport::NONE;
        let p_e = PowerModel::with_defaults(cfg.clone()).dense(None);
        cfg.dac = DacKind::optimal_eodac();
        let p_eo = PowerModel::with_defaults(cfg).dense(None);
        let ratio = p_e.input_dac_mw / p_eo.input_dac_mw;
        assert!((ratio - 2.2857).abs() < 1e-3, "ratio={ratio}");
    }

    #[test]
    fn dense_chunk_times_slots_equals_dense_closed_form() {
        // chunk() with all-true masks and uniform |phi| must reproduce the
        // closed-form dense() at the same mean phase, scaled by the slot
        // count (chunk() is per-slot).
        let m = model(SparsitySupport::NONE, 4);
        let (rows, cols) = m.cfg.chunk_shape();
        let slots = (m.cfg.n_cores() / (m.cfg.share_r * m.cfg.share_c)) as f64;
        let phi = 0.37;
        let phases = vec![phi; rows * cols];
        let p_chunk = m
            .chunk(&phases, &vec![true; cols], &vec![true; rows], 0.0)
            .scaled(slots);
        let p_dense = m.dense(Some(phi));
        assert!((p_chunk.total_mw() - p_dense.total_mw()).abs() < 1e-6);
        assert!((p_chunk.weight_mzi_mw - p_dense.weight_mzi_mw).abs() < 1e-6);
    }
}

//! Energy accounting over a model's execution (§4.1 evaluation metrics):
//!
//! ```text
//!   E_tot = Σ_l Σ_i Σ_j  P^l_{i,j} · Cyc^l_{i,j} / f
//!   P_avg = E_tot / (Cyc_tot / f)
//! ```
//!
//! A row-column sparse chunk takes the *same* 1 cycle as a dense chunk
//! (the paper's clarification), so PAP = P_avg · Area is equivalent to
//! TOPS/W/mm² ranking at fixed speed.

use super::model::PowerBreakdown;

#[derive(Debug, Clone, Default)]
pub struct EnergyAccumulator {
    total_cycle_mw: f64,
    total_cycles: u64,
    /// Wall-clock cycles: chunk waves overlap across slots, so wall time
    /// is shorter than the per-chunk cycle sum. 0 ⇒ fall back to the sum.
    wall_cycles: u64,
    per_layer: Vec<(String, f64, u64)>,
}

#[derive(Debug, Clone)]
pub struct EnergyReport {
    /// Total energy in mJ.
    pub energy_mj: f64,
    /// Average power in W.
    pub p_avg_w: f64,
    /// Total cycles.
    pub cycles: u64,
    /// Wall time in ms at the configured clock.
    pub time_ms: f64,
    /// Per-layer (name, energy mJ, cycles).
    pub per_layer: Vec<(String, f64, u64)>,
}

impl EnergyAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `cycles` cycles of execution at the given power draw.
    pub fn record(&mut self, layer: &str, power: &PowerBreakdown, cycles: u64) {
        let mw_cycles = power.total_mw() * cycles as f64;
        self.total_cycle_mw += mw_cycles;
        self.total_cycles += cycles;
        match self.per_layer.iter_mut().find(|(n, _, _)| n == layer) {
            Some(entry) => {
                entry.1 += mw_cycles;
                entry.2 += cycles;
            }
            None => self.per_layer.push((layer.to_string(), mw_cycles, cycles)),
        }
    }

    /// Record wall-clock progress (e.g. `LayerSchedule::wall_cycles`).
    pub fn advance_wall(&mut self, cycles: u64) {
        self.wall_cycles += cycles;
    }

    /// Finalize at clock `freq_ghz`.
    pub fn report(&self, freq_ghz: f64) -> EnergyReport {
        // mW · cycles / (GHz) = mW · ns = pJ;  pJ → mJ is 1e-9.
        let to_mj = |mw_cycles: f64| mw_cycles / freq_ghz * 1e-9;
        let energy_mj = to_mj(self.total_cycle_mw);
        let clock_cycles = if self.wall_cycles > 0 { self.wall_cycles } else { self.total_cycles };
        let time_ms = clock_cycles as f64 / freq_ghz * 1e-6;
        let p_avg_w = if clock_cycles == 0 {
            0.0
        } else {
            // mJ / ms = W
            energy_mj / time_ms
        };
        EnergyReport {
            energy_mj,
            p_avg_w,
            cycles: clock_cycles,
            time_ms,
            per_layer: self
                .per_layer
                .iter()
                .map(|(n, mwc, cyc)| (n.clone(), to_mj(*mwc), *cyc))
                .collect(),
        }
    }
}

/// Power-area product (W·mm²) — the paper's scalar design objective.
pub fn pap(p_avg_w: f64, area_mm2: f64) -> f64 {
    p_avg_w * area_mm2
}

/// Area-energy efficiency in TOPS/W/mm² for a (k1,k2) MAC array running at
/// `freq_ghz` with `n_cores` cores: ops/cycle = 2·k1·k2·cores.
pub fn tops_per_w_mm2(
    k1: usize,
    k2: usize,
    n_cores: usize,
    freq_ghz: f64,
    p_avg_w: f64,
    area_mm2: f64,
) -> f64 {
    let ops_per_s = 2.0 * (k1 * k2 * n_cores) as f64 * freq_ghz * 1e9;
    ops_per_s / 1e12 / p_avg_w / area_mm2
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bd(mw: f64) -> PowerBreakdown {
        PowerBreakdown { weight_mzi_mw: mw, ..Default::default() }
    }

    #[test]
    fn constant_power_average() {
        let mut acc = EnergyAccumulator::new();
        acc.record("l1", &bd(2000.0), 100);
        acc.record("l2", &bd(2000.0), 300);
        let r = acc.report(5.0);
        assert!((r.p_avg_w - 2.0).abs() < 1e-12, "P_avg = 2 W");
        assert_eq!(r.cycles, 400);
        // E = 2 W * 400 cycles / 5 GHz = 2 * 80 ns = 160 nJ = 1.6e-4 mJ
        assert!((r.energy_mj - 1.6e-4).abs() < 1e-12);
        assert_eq!(r.per_layer.len(), 2);
    }

    #[test]
    fn weighted_average_power() {
        let mut acc = EnergyAccumulator::new();
        acc.record("a", &bd(1000.0), 100); // 1 W for 100 cyc
        acc.record("b", &bd(3000.0), 300); // 3 W for 300 cyc
        let r = acc.report(1.0);
        assert!((r.p_avg_w - 2.5).abs() < 1e-12);
    }

    #[test]
    fn layer_aggregation() {
        let mut acc = EnergyAccumulator::new();
        acc.record("conv1", &bd(1000.0), 10);
        acc.record("conv1", &bd(1000.0), 10);
        let r = acc.report(5.0);
        assert_eq!(r.per_layer.len(), 1);
        assert_eq!(r.per_layer[0].2, 20);
    }

    #[test]
    fn tops_metric_sane() {
        // 16 cores of 16x16 at 5 GHz = 2*256*16*5e9 = 40.96 TOPS
        let t = tops_per_w_mm2(16, 16, 16, 5.0, 10.0, 20.0);
        assert!((t - 40.96 / 10.0 / 20.0).abs() < 1e-9);
    }

    #[test]
    fn empty_report_is_zero() {
        let r = EnergyAccumulator::new().report(5.0);
        assert_eq!(r.p_avg_w, 0.0);
        assert_eq!(r.energy_mj, 0.0);
    }
}

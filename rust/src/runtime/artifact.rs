//! Artifact registry + PJRT execution.
//!
//! The real implementation rides on the external `xla` crate, which the
//! offline toolchain cannot fetch; it is gated behind the `pjrt` feature
//! (see Cargo.toml). Without the feature, a stub with the same API
//! reports itself unavailable so every call site degrades gracefully —
//! `scatter info`, the coordinator bench, quickstart and the integration
//! tests all already handle the Err path.

#[cfg(feature = "pjrt")]
mod imp {
    use crate::{Error, Result};
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};

    /// One compiled executable, ready to run.
    pub struct CompiledArtifact {
        pub name: String,
        pub path: PathBuf,
        exe: xla::PjRtLoadedExecutable,
    }

    impl CompiledArtifact {
        /// Execute with f32 input buffers of the given shapes.
        ///
        /// AOT artifacts are lowered with `return_tuple=True`, so the result
        /// is a 1-tuple whose element we flatten to `Vec<f32>`.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = lit
                    .reshape(&dims)
                    .map_err(|e| Error::Runtime(format!("reshape input: {e:?}")))?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Runtime(format!("execute {}: {e:?}", self.name)))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("fetch result: {e:?}")))?;
            let out = lit
                .to_tuple1()
                .map_err(|e| Error::Runtime(format!("untuple result: {e:?}")))?;
            out.to_vec::<f32>().map_err(|e| Error::Runtime(format!("read result: {e:?}")))
        }
    }

    /// Loads HLO-text artifacts onto a shared PJRT CPU client and caches the
    /// compiled executables.
    pub struct ArtifactRuntime {
        client: xla::PjRtClient,
        root: PathBuf,
        cache: BTreeMap<String, CompiledArtifact>,
    }

    impl ArtifactRuntime {
        /// Create against an artifacts directory (usually `artifacts/`).
        pub fn new(root: impl AsRef<Path>) -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("PJRT CPU client: {e:?}")))?;
            Ok(Self { client, root: root.as_ref().to_path_buf(), cache: BTreeMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Path for a named artifact: `<root>/<name>.hlo.txt`.
        pub fn artifact_path(&self, name: &str) -> PathBuf {
            self.root.join(format!("{name}.hlo.txt"))
        }

        pub fn has_artifact(&self, name: &str) -> bool {
            self.artifact_path(name).exists()
        }

        /// Load + compile (cached).
        pub fn load(&mut self, name: &str) -> Result<&CompiledArtifact> {
            if !self.cache.contains_key(name) {
                let path = self.artifact_path(name);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
                )
                .map_err(|e| Error::Runtime(format!("parse {}: {e:?}", path.display())))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| Error::Runtime(format!("compile {name}: {e:?}")))?;
                self.cache.insert(
                    name.to_string(),
                    CompiledArtifact { name: name.to_string(), path, exe },
                );
            }
            Ok(&self.cache[name])
        }

        /// Convenience: load and run in one call.
        pub fn run_f32(
            &mut self,
            name: &str,
            inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<f32>> {
            self.load(name)?;
            self.cache[name].run_f32(inputs)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::Error;

        // Full artifact round-trip tests live in rust/tests/runtime_artifacts.rs
        // (they need `make artifacts` to have run). Here we only check the
        // client comes up and missing artifacts error cleanly.

        #[test]
        fn client_comes_up() {
            let rt = ArtifactRuntime::new("artifacts").expect("PJRT CPU client");
            assert!(!rt.platform().is_empty());
        }

        #[test]
        fn missing_artifact_is_clean_error() {
            let mut rt = ArtifactRuntime::new("artifacts").unwrap();
            match rt.load("definitely_not_there") {
                Err(Error::Runtime(msg)) => {
                    assert!(msg.contains("definitely_not_there") || msg.contains("parse"))
                }
                Err(other) => panic!("unexpected error: {other}"),
                Ok(_) => panic!("expected an error for a missing artifact"),
            }
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::{Error, Result};
    use std::path::{Path, PathBuf};

    const UNAVAILABLE: &str =
        "PJRT runtime not compiled in (build with `--features pjrt` after adding \
         the `xla` dependency on a networked machine)";

    /// Stub compiled artifact (never constructed without the feature).
    pub struct CompiledArtifact {
        pub name: String,
        pub path: PathBuf,
    }

    impl CompiledArtifact {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
            Err(Error::Runtime(UNAVAILABLE.into()))
        }
    }

    /// Stub runtime: construction fails with a clear message so every
    /// call site takes its existing artifacts-unavailable path.
    pub struct ArtifactRuntime {
        root: PathBuf,
    }

    impl ArtifactRuntime {
        pub fn new(_root: impl AsRef<Path>) -> Result<Self> {
            Err(Error::Runtime(UNAVAILABLE.into()))
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn artifact_path(&self, name: &str) -> PathBuf {
            self.root.join(format!("{name}.hlo.txt"))
        }

        pub fn has_artifact(&self, name: &str) -> bool {
            self.artifact_path(name).exists()
        }

        pub fn load(&mut self, _name: &str) -> Result<&CompiledArtifact> {
            Err(Error::Runtime(UNAVAILABLE.into()))
        }

        pub fn run_f32(
            &mut self,
            _name: &str,
            _inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<f32>> {
            Err(Error::Runtime(UNAVAILABLE.into()))
        }
    }
}

pub use imp::{ArtifactRuntime, CompiledArtifact};

use crate::sparsity::LayerMask;
use crate::util::Json;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// FNV-1a 64-bit over a byte string — the artifact content hash. A
/// dependency-free stand-in for a cryptographic digest: it detects the
/// corruption classes the loader must catch (truncation, bit rot,
/// hand-edits), not adversaries.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A versioned sparsity artifact: one generation of the co-design loop.
///
/// The serving side treats this as the unit of hot-swap — a monotone
/// `generation` id, the full per-layer mask set the DST job emitted, the
/// job's rerouter-power estimate, and the serving power observed when
/// the job ran (its input signal, kept for provenance). The JSON form
/// carries a content hash over the canonical payload so a truncated or
/// hand-edited file can never load as a silently-wrong mask set.
#[derive(Debug, Clone)]
pub struct MaskArtifact {
    /// Monotone generation id; the swap protocol refuses to move
    /// backwards or sideways.
    pub generation: u64,
    /// Per-layer masks (same keying as `PhotonicEngine::set_masks`).
    pub masks: BTreeMap<String, LayerMask>,
    /// Estimated rerouter power of this mask set (mW), from
    /// `sparsity::mask_power_mw` over every chunk.
    pub power_mw: f64,
    /// Average serving power (W) observed on the energy ledger when the
    /// DST job produced this candidate; 0 when unknown.
    pub observed_power_w: f64,
}

impl MaskArtifact {
    pub fn new(
        generation: u64,
        masks: BTreeMap<String, LayerMask>,
        power_mw: f64,
        observed_power_w: f64,
    ) -> Self {
        Self { generation, masks, power_mw, observed_power_w }
    }

    /// Canonical payload JSON (everything except the hash). The hash is
    /// computed over this exact rendering, so payload and digest can
    /// never drift apart across save/load.
    fn payload_json(&self) -> Json {
        Json::obj(vec![
            ("generation", Json::Num(self.generation as f64)),
            (
                "masks",
                Json::Obj(
                    self.masks
                        .iter()
                        .map(|(name, lm)| (name.clone(), lm.to_json()))
                        .collect(),
                ),
            ),
            ("power_mw", Json::Num(self.power_mw)),
            ("observed_power_w", Json::Num(self.observed_power_w)),
        ])
    }

    /// Content hash over the canonical payload rendering.
    pub fn content_hash(&self) -> u64 {
        fnv1a64(self.payload_json().to_string().as_bytes())
    }

    /// Full JSON document: payload fields plus the content hash (hex —
    /// a JSON number is an f64 and cannot carry 64 bits exactly).
    pub fn to_json(&self) -> Json {
        let hash = self.content_hash();
        let Json::Obj(mut fields) = self.payload_json() else { unreachable!() };
        fields.insert("hash".into(), Json::Str(format!("{hash:016x}")));
        Json::Obj(fields)
    }

    /// Parse and verify a JSON document produced by [`Self::to_json`].
    /// A missing or mismatched hash is a typed [`Error::Serde`] — never
    /// a silent load of corrupted masks.
    pub fn from_json(v: &Json) -> Result<Self> {
        let generation = v
            .get("generation")
            .and_then(Json::as_f64)
            .filter(|g| *g >= 0.0)
            .map(|g| g as u64)
            .ok_or_else(|| Error::Serde("mask artifact missing 'generation'".into()))?;
        let masks_obj = v
            .get("masks")
            .ok_or_else(|| Error::Serde("mask artifact missing 'masks'".into()))?;
        let Json::Obj(entries) = masks_obj else {
            return Err(Error::Serde("mask artifact 'masks' is not an object".into()));
        };
        let mut masks = BTreeMap::new();
        for (name, lm) in entries {
            masks.insert(name.clone(), LayerMask::from_json(lm)?);
        }
        let power_mw = v.get("power_mw").and_then(Json::as_f64).unwrap_or(0.0);
        let observed_power_w =
            v.get("observed_power_w").and_then(Json::as_f64).unwrap_or(0.0);
        let artifact = Self { generation, masks, power_mw, observed_power_w };
        let stored = v
            .get("hash")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Serde("mask artifact missing 'hash'".into()))?;
        let expect = format!("{:016x}", artifact.content_hash());
        if stored != expect {
            return Err(Error::Serde(format!(
                "mask artifact generation {generation}: content hash {stored} does \
                 not match payload ({expect}) — corrupted or hand-edited artifact"
            )));
        }
        Ok(artifact)
    }

    /// On-disk name for this generation.
    pub fn file_name(&self) -> String {
        format!("mask_gen_{:06}.json", self.generation)
    }

    /// Atomic persistence: write `<name>.tmp`, then rename into place.
    /// A crash mid-write leaves the previous generation intact and at
    /// worst a stale `.tmp`; readers can never observe a half-written
    /// artifact. Returns the final path.
    pub fn save_atomic(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .map_err(|e| Error::Runtime(format!("create {}: {e}", dir.display())))?;
        let final_path = dir.join(self.file_name());
        let tmp = dir.join(format!("{}.tmp", self.file_name()));
        std::fs::write(&tmp, self.to_json().to_string())
            .map_err(|e| Error::Runtime(format!("write {}: {e}", tmp.display())))?;
        std::fs::rename(&tmp, &final_path)
            .map_err(|e| Error::Runtime(format!("rename {}: {e}", tmp.display())))?;
        Ok(final_path)
    }

    /// Load and verify one artifact file. Unreadable files are
    /// [`Error::Runtime`]; unparseable or hash-mismatched content is
    /// [`Error::Serde`].
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Runtime(format!("read {}: {e}", path.display())))?;
        let v = Json::parse(&text).map_err(|e| {
            Error::Serde(format!("parse {}: {e} (truncated artifact?)", path.display()))
        })?;
        Self::from_json(&v)
    }

    /// Startup scan of an artifact directory: load and verify every
    /// file, keep what checks out (sorted by ascending generation), and
    /// **skip-and-count** everything else — truncated writes, bit rot
    /// caught by the content hash, stale `.tmp` leftovers, foreign
    /// files someone dropped in the directory. A serving process
    /// resuming over a damaged directory must come up on the artifacts
    /// that survive, not crash on the ones that did not; the skip count
    /// feeds `scatter_artifacts_skipped_total` so the damage is visible
    /// instead of silent. A missing or unreadable directory is simply
    /// empty (fresh deployments have no artifact history).
    pub fn scan_dir(dir: &Path) -> (Vec<Self>, usize) {
        let Ok(entries) = std::fs::read_dir(dir) else { return (Vec::new(), 0) };
        let mut artifacts = Vec::new();
        let mut skipped = 0usize;
        for entry in entries.flatten() {
            let path = entry.path();
            if path.is_dir() {
                continue;
            }
            match Self::load(&path) {
                Ok(a) => artifacts.push(a),
                Err(_) => skipped += 1,
            }
        }
        artifacts.sort_by_key(|a| a.generation);
        (artifacts, skipped)
    }

    /// Load with the monotone-generation invariant enforced: the file's
    /// generation must be strictly greater than `prior_gen`, otherwise a
    /// stale artifact could roll a replica backwards unnoticed.
    pub fn load_monotone(path: &Path, prior_gen: u64) -> Result<Self> {
        let artifact = Self::load(path)?;
        if artifact.generation <= prior_gen {
            return Err(Error::Runtime(format!(
                "non-monotone mask artifact {}: generation {} <= prior {} — \
                 refusing a stale or replayed artifact",
                path.display(),
                artifact.generation,
                prior_gen
            )));
        }
        Ok(artifact)
    }
}

#[cfg(test)]
mod mask_artifact_tests {
    use super::*;

    fn sample(generation: u64) -> MaskArtifact {
        let mut masks = BTreeMap::new();
        let mut lm = LayerMask::dense(1, 2, 4, 8);
        lm.chunk_mut(0, 1).col = vec![true, false, true, false, true, false, true, false];
        masks.insert("conv2".to_string(), lm);
        masks.insert("conv3".to_string(), LayerMask::dense(2, 1, 4, 8));
        MaskArtifact::new(generation, masks, 12.5, 3.25)
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("scatter_artifact_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let a = sample(7);
        let text = a.to_json().to_string();
        let back = MaskArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.generation, 7);
        assert_eq!(back.power_mw, 12.5);
        assert_eq!(back.observed_power_w, 3.25);
        assert_eq!(back.masks.len(), 2);
        assert_eq!(
            back.masks["conv2"].chunk(0, 1),
            a.masks["conv2"].chunk(0, 1),
            "mask bits survive the round-trip"
        );
        assert_eq!(back.content_hash(), a.content_hash());
    }

    #[test]
    fn save_atomic_then_load_and_no_tmp_left() {
        let dir = tmp_dir("atomic");
        let a = sample(3);
        let path = a.save_atomic(&dir).expect("save");
        assert_eq!(path.file_name().unwrap().to_str().unwrap(), "mask_gen_000003.json");
        let back = MaskArtifact::load(&path).expect("load");
        assert_eq!(back.generation, 3);
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "tmp"))
            .collect();
        assert!(leftovers.is_empty(), "write-then-rename leaves no tmp file");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_file_is_typed_serde_error() {
        let dir = tmp_dir("trunc");
        let a = sample(5);
        let path = a.save_atomic(&dir).expect("save");
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        match MaskArtifact::load(&path) {
            Err(Error::Serde(msg)) => assert!(
                msg.contains("truncated") || msg.contains("parse"),
                "message should point at the parse failure: {msg}"
            ),
            other => panic!("truncated artifact must be Serde error, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tampered_content_fails_the_hash_check() {
        let dir = tmp_dir("hash");
        let a = sample(9);
        let path = a.save_atomic(&dir).expect("save");
        // flip one mask bit without touching the stored hash
        let text = std::fs::read_to_string(&path).unwrap();
        let tampered = text.replacen("false", "true", 1);
        assert_ne!(text, tampered, "sample must contain a pruned bit to flip");
        std::fs::write(&path, tampered).unwrap();
        match MaskArtifact::load(&path) {
            Err(Error::Serde(msg)) => {
                assert!(msg.contains("hash"), "error must name the hash check: {msg}")
            }
            other => panic!("tampered artifact must fail the hash check, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_stored_hash_field_fails() {
        let a = sample(2);
        let text = a.to_json().to_string();
        let expect = format!("{:016x}", a.content_hash());
        let bad = text.replace(&expect, "deadbeefdeadbeef");
        match MaskArtifact::from_json(&Json::parse(&bad).unwrap()) {
            Err(Error::Serde(msg)) => assert!(msg.contains("hash"), "{msg}"),
            other => panic!("bad hash field must error, got {other:?}"),
        }
    }

    #[test]
    fn non_monotone_generation_is_typed_error() {
        let dir = tmp_dir("mono");
        let path = sample(4).save_atomic(&dir).expect("save");
        assert_eq!(
            MaskArtifact::load_monotone(&path, 3).expect("4 > 3 loads").generation,
            4
        );
        for prior in [4u64, 10] {
            match MaskArtifact::load_monotone(&path, prior) {
                Err(Error::Runtime(msg)) => assert!(
                    msg.contains("non-monotone") && msg.contains("generation 4"),
                    "error must name the stale generation: {msg}"
                ),
                other => panic!("gen 4 vs prior {prior} must error, got {other:?}"),
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_fields_are_typed_errors() {
        for (doc, needle) in [
            ("{}", "generation"),
            ("{\"generation\": 1}", "masks"),
            ("{\"generation\": 1, \"masks\": {}}", "hash"),
        ] {
            match MaskArtifact::from_json(&Json::parse(doc).unwrap()) {
                Err(Error::Serde(msg)) => {
                    assert!(msg.contains(needle), "want {needle:?} in {msg:?}")
                }
                other => panic!("doc {doc} must be Serde error, got {other:?}"),
            }
        }
    }

    #[test]
    fn scan_dir_skips_and_counts_damage() {
        let dir = tmp_dir("scan");
        sample(2).save_atomic(&dir).expect("save");
        sample(7).save_atomic(&dir).expect("save");
        let victim = sample(4).save_atomic(&dir).expect("save");
        // truncate one artifact mid-payload
        let full = std::fs::read_to_string(&victim).unwrap();
        std::fs::write(&victim, &full[..full.len() / 3]).unwrap();
        // bit-flip another without updating its hash
        let flipped = sample(5).save_atomic(&dir).expect("save");
        let text = std::fs::read_to_string(&flipped).unwrap();
        std::fs::write(&flipped, text.replacen("false", "true", 1)).unwrap();
        // foreign files: a note someone left, and a crashed write's .tmp
        std::fs::write(dir.join("README.txt"), "masks live here").unwrap();
        std::fs::write(dir.join("mask_gen_000009.json.tmp"), "{\"gener").unwrap();
        // a subdirectory is ignored entirely (neither kept nor counted)
        std::fs::create_dir_all(dir.join("archive")).unwrap();

        let (arts, skipped) = MaskArtifact::scan_dir(&dir);
        assert_eq!(
            arts.iter().map(|a| a.generation).collect::<Vec<_>>(),
            vec![2, 7],
            "only verified artifacts load, in generation order"
        );
        assert_eq!(skipped, 4, "truncated + bit-flipped + 2 foreign files");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_dir_of_missing_directory_is_empty() {
        let dir = tmp_dir("scan_missing"); // created lazily — never written
        let (arts, skipped) = MaskArtifact::scan_dir(&dir);
        assert!(arts.is_empty());
        assert_eq!(skipped, 0, "a fresh deployment has nothing to skip");
    }

    #[test]
    fn scan_dir_survives_all_garbage_directory() {
        let dir = tmp_dir("scan_garbage");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.json"), "not json at all").unwrap();
        std::fs::write(dir.join("b.json"), "{\"generation\": 1}").unwrap();
        std::fs::write(dir.join("c.bin"), [0u8, 159, 146, 150]).unwrap();
        let (arts, skipped) = MaskArtifact::scan_dir(&dir);
        assert!(arts.is_empty(), "nothing verifiable in the rubble");
        assert_eq!(skipped, 3, "every damaged file is counted");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn chunk_mask_corruption_inside_artifact_surfaces() {
        // a structurally-valid document whose mask payload is broken must
        // surface the LayerMask error, not a stale/partial artifact
        let doc = "{\"generation\": 1, \"masks\": {\"conv2\": {\"p\": 1, \"q\": 1, \
                   \"chunks\": [{\"row\": [true]}]}}, \"power_mw\": 0, \
                   \"observed_power_w\": 0, \"hash\": \"0000000000000000\"}";
        match MaskArtifact::from_json(&Json::parse(doc).unwrap()) {
            Err(Error::Serde(msg)) => assert!(msg.contains("col"), "{msg}"),
            other => panic!("broken chunk mask must error, got {other:?}"),
        }
    }
}

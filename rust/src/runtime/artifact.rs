//! Artifact registry + PJRT execution.
//!
//! The real implementation rides on the external `xla` crate, which the
//! offline toolchain cannot fetch; it is gated behind the `pjrt` feature
//! (see Cargo.toml). Without the feature, a stub with the same API
//! reports itself unavailable so every call site degrades gracefully —
//! `scatter info`, the coordinator bench, quickstart and the integration
//! tests all already handle the Err path.

#[cfg(feature = "pjrt")]
mod imp {
    use crate::{Error, Result};
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};

    /// One compiled executable, ready to run.
    pub struct CompiledArtifact {
        pub name: String,
        pub path: PathBuf,
        exe: xla::PjRtLoadedExecutable,
    }

    impl CompiledArtifact {
        /// Execute with f32 input buffers of the given shapes.
        ///
        /// AOT artifacts are lowered with `return_tuple=True`, so the result
        /// is a 1-tuple whose element we flatten to `Vec<f32>`.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = lit
                    .reshape(&dims)
                    .map_err(|e| Error::Runtime(format!("reshape input: {e:?}")))?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Runtime(format!("execute {}: {e:?}", self.name)))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("fetch result: {e:?}")))?;
            let out = lit
                .to_tuple1()
                .map_err(|e| Error::Runtime(format!("untuple result: {e:?}")))?;
            out.to_vec::<f32>().map_err(|e| Error::Runtime(format!("read result: {e:?}")))
        }
    }

    /// Loads HLO-text artifacts onto a shared PJRT CPU client and caches the
    /// compiled executables.
    pub struct ArtifactRuntime {
        client: xla::PjRtClient,
        root: PathBuf,
        cache: BTreeMap<String, CompiledArtifact>,
    }

    impl ArtifactRuntime {
        /// Create against an artifacts directory (usually `artifacts/`).
        pub fn new(root: impl AsRef<Path>) -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("PJRT CPU client: {e:?}")))?;
            Ok(Self { client, root: root.as_ref().to_path_buf(), cache: BTreeMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Path for a named artifact: `<root>/<name>.hlo.txt`.
        pub fn artifact_path(&self, name: &str) -> PathBuf {
            self.root.join(format!("{name}.hlo.txt"))
        }

        pub fn has_artifact(&self, name: &str) -> bool {
            self.artifact_path(name).exists()
        }

        /// Load + compile (cached).
        pub fn load(&mut self, name: &str) -> Result<&CompiledArtifact> {
            if !self.cache.contains_key(name) {
                let path = self.artifact_path(name);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
                )
                .map_err(|e| Error::Runtime(format!("parse {}: {e:?}", path.display())))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| Error::Runtime(format!("compile {name}: {e:?}")))?;
                self.cache.insert(
                    name.to_string(),
                    CompiledArtifact { name: name.to_string(), path, exe },
                );
            }
            Ok(&self.cache[name])
        }

        /// Convenience: load and run in one call.
        pub fn run_f32(
            &mut self,
            name: &str,
            inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<f32>> {
            self.load(name)?;
            self.cache[name].run_f32(inputs)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::Error;

        // Full artifact round-trip tests live in rust/tests/runtime_artifacts.rs
        // (they need `make artifacts` to have run). Here we only check the
        // client comes up and missing artifacts error cleanly.

        #[test]
        fn client_comes_up() {
            let rt = ArtifactRuntime::new("artifacts").expect("PJRT CPU client");
            assert!(!rt.platform().is_empty());
        }

        #[test]
        fn missing_artifact_is_clean_error() {
            let mut rt = ArtifactRuntime::new("artifacts").unwrap();
            match rt.load("definitely_not_there") {
                Err(Error::Runtime(msg)) => {
                    assert!(msg.contains("definitely_not_there") || msg.contains("parse"))
                }
                Err(other) => panic!("unexpected error: {other}"),
                Ok(_) => panic!("expected an error for a missing artifact"),
            }
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::{Error, Result};
    use std::path::{Path, PathBuf};

    const UNAVAILABLE: &str =
        "PJRT runtime not compiled in (build with `--features pjrt` after adding \
         the `xla` dependency on a networked machine)";

    /// Stub compiled artifact (never constructed without the feature).
    pub struct CompiledArtifact {
        pub name: String,
        pub path: PathBuf,
    }

    impl CompiledArtifact {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
            Err(Error::Runtime(UNAVAILABLE.into()))
        }
    }

    /// Stub runtime: construction fails with a clear message so every
    /// call site takes its existing artifacts-unavailable path.
    pub struct ArtifactRuntime {
        root: PathBuf,
    }

    impl ArtifactRuntime {
        pub fn new(_root: impl AsRef<Path>) -> Result<Self> {
            Err(Error::Runtime(UNAVAILABLE.into()))
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn artifact_path(&self, name: &str) -> PathBuf {
            self.root.join(format!("{name}.hlo.txt"))
        }

        pub fn has_artifact(&self, name: &str) -> bool {
            self.artifact_path(name).exists()
        }

        pub fn load(&mut self, _name: &str) -> Result<&CompiledArtifact> {
            Err(Error::Runtime(UNAVAILABLE.into()))
        }

        pub fn run_f32(
            &mut self,
            _name: &str,
            _inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<f32>> {
            Err(Error::Runtime(UNAVAILABLE.into()))
        }
    }
}

pub use imp::{ArtifactRuntime, CompiledArtifact};

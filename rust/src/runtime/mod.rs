//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them natively.
//!
//! Python runs only at build time; this module is the request-path bridge.
//! Interchange format is **HLO text**, not serialized protos — jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;

pub use artifact::{ArtifactRuntime, CompiledArtifact};

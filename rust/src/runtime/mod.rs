//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them natively.
//!
//! Python runs only at build time; this module is the request-path bridge.
//! Interchange format is **HLO text**, not serialized protos — jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! [`MaskArtifact`] is the feature-independent half: the versioned,
//! content-hashed sparsity artifact the in-serving DST loop emits and
//! the hot-swap protocol consumes (atomic write-then-rename persistence,
//! monotone generation ids).

pub mod artifact;

pub use artifact::{ArtifactRuntime, CompiledArtifact, MaskArtifact};

//! im2col lowering of 2-D convolution (§3.3.5: the unfolded weight matrix
//! is what gets partitioned into rk1×ck2 chunks and mapped onto PTCs).

use super::tensor::Tensor;

/// Unfold a CHW input into the patch matrix for a k×k convolution with
/// given stride and zero padding.
///
/// Returns (patches, out_h, out_w) where `patches` is row-major
/// `(C·k·k) × (out_h·out_w)`: one column per output pixel.
pub fn im2col(
    input: &Tensor,
    k: usize,
    stride: usize,
    pad: usize,
) -> (Vec<f64>, usize, usize) {
    assert_eq!(input.ndim(), 3, "im2col expects CHW");
    let (c, h, w) = (input.shape[0], input.shape[1], input.shape[2]);
    assert!(h + 2 * pad >= k && w + 2 * pad >= k, "kernel larger than padded input");
    let out_h = (h + 2 * pad - k) / stride + 1;
    let out_w = (w + 2 * pad - k) / stride + 1;
    let n_cols = out_h * out_w;
    let n_rows = c * k * k;
    let mut patches = vec![0.0f64; n_rows * n_cols];
    for ci in 0..c {
        for ki in 0..k {
            for kj in 0..k {
                let row = (ci * k + ki) * k + kj;
                let dst = &mut patches[row * n_cols..(row + 1) * n_cols];
                let mut col = 0usize;
                for oy in 0..out_h {
                    let iy = oy * stride + ki;
                    for ox in 0..out_w {
                        let ix = ox * stride + kj;
                        // account for padding offset
                        let v = if iy >= pad && ix >= pad && iy - pad < h && ix - pad < w {
                            input.at3(ci, iy - pad, ix - pad)
                        } else {
                            0.0
                        };
                        dst[col] = v;
                        col += 1;
                    }
                }
            }
        }
    }
    (patches, out_h, out_w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1_kernel() {
        let t = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let (p, oh, ow) = im2col(&t, 1, 1, 0);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(p, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn same_conv_shape() {
        let t = Tensor::zeros(&[3, 8, 8]);
        let (p, oh, ow) = im2col(&t, 3, 1, 1);
        assert_eq!((oh, ow), (8, 8));
        assert_eq!(p.len(), 3 * 9 * 64);
    }

    #[test]
    fn stride_two_downsamples() {
        let t = Tensor::zeros(&[1, 8, 8]);
        let (_, oh, ow) = im2col(&t, 3, 2, 1);
        assert_eq!((oh, ow), (4, 4));
    }

    #[test]
    fn known_3x3_patch_values() {
        // 1 channel 3x3 input, 3x3 kernel, no pad -> single column = input
        let t = Tensor::from_vec(&[1, 3, 3], (1..=9).map(|x| x as f64).collect());
        let (p, oh, ow) = im2col(&t, 3, 1, 0);
        assert_eq!((oh, ow), (1, 1));
        assert_eq!(p, (1..=9).map(|x| x as f64).collect::<Vec<_>>());
    }

    #[test]
    fn padding_zeros_at_border() {
        let t = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let (p, oh, ow) = im2col(&t, 3, 1, 1);
        assert_eq!((oh, ow), (2, 2));
        // row 0 = kernel position (0,0): for output (0,0) that's input(-1,-1) = 0
        assert_eq!(p[0], 0.0);
        // center kernel position (1,1), output (0,0) -> input (0,0) = 1
        let row_center = (0 * 3 + 1) * 3 + 1;
        assert_eq!(p[row_center * 4], 1.0);
    }

    #[test]
    fn conv_via_im2col_matches_direct() {
        // direct 2d conv vs im2col + dot product
        let mut rng = crate::util::XorShiftRng::new(5);
        let mut data = vec![0.0; 2 * 5 * 5];
        rng.fill_uniform(&mut data, -1.0, 1.0);
        let input = Tensor::from_vec(&[2, 5, 5], data);
        let mut kern = vec![0.0; 2 * 3 * 3];
        rng.fill_uniform(&mut kern, -1.0, 1.0);
        let (p, oh, ow) = im2col(&input, 3, 1, 1);
        // im2col result for output channel 0
        let n_cols = oh * ow;
        let mut y = vec![0.0; n_cols];
        for r in 0..kern.len() {
            for col in 0..n_cols {
                y[col] += kern[r] * p[r * n_cols + col];
            }
        }
        // direct convolution at a few positions
        for (oy, ox) in [(0usize, 0usize), (2, 3), (4, 4)] {
            let mut acc = 0.0;
            for ci in 0..2 {
                for ki in 0..3 {
                    for kj in 0..3 {
                        let iy = oy as isize + ki as isize - 1;
                        let ix = ox as isize + kj as isize - 1;
                        if iy >= 0 && ix >= 0 && iy < 5 && ix < 5 {
                            acc += kern[(ci * 3 + ki) * 3 + kj]
                                * input.at3(ci, iy as usize, ix as usize);
                        }
                    }
                }
            }
            assert!((y[oy * ow + ox] - acc).abs() < 1e-12);
        }
    }
}

//! im2col lowering of 2-D convolution (§3.3.5: the unfolded weight matrix
//! is what gets partitioned into rk1×ck2 chunks and mapped onto PTCs).
//!
//! [`im2col_batch`] lowers a whole [`BatchTensor`] at once into a single
//! `(C·k·k) × (batch·out_h·out_w)` patch matrix with **item-major
//! columns** — the column-offset convention the batched forward path and
//! the engine's per-(chunk, column) noise streams share.

use super::tensor::{BatchTensor, Tensor};

/// Unfold a CHW input into the patch matrix for a k×k convolution with
/// given stride and zero padding.
///
/// Returns (patches, out_h, out_w) where `patches` is row-major
/// `(C·k·k) × (out_h·out_w)`: one column per output pixel.
pub fn im2col(
    input: &Tensor,
    k: usize,
    stride: usize,
    pad: usize,
) -> (Vec<f64>, usize, usize) {
    assert_eq!(input.ndim(), 3, "im2col expects CHW");
    let (c, h, w) = (input.shape[0], input.shape[1], input.shape[2]);
    let (out_h, out_w) = out_shape(h, w, k, stride, pad);
    let n_cols = out_h * out_w;
    let n_rows = c * k * k;
    let mut patches = vec![0.0f64; n_rows * n_cols];
    for ci in 0..c {
        for ki in 0..k {
            for kj in 0..k {
                let row = (ci * k + ki) * k + kj;
                let dst = &mut patches[row * n_cols..(row + 1) * n_cols];
                fill_patch_row(&input.data, h, w, ci, ki, kj, stride, pad, out_h, out_w, dst);
            }
        }
    }
    (patches, out_h, out_w)
}

/// Batched im2col: unfold every item of a CHW batch into ONE patch
/// matrix, row-major `(C·k·k) × (batch·out_h·out_w)` with item-major
/// columns — item `b`'s output pixels occupy columns
/// `[b·out_h·out_w, (b+1)·out_h·out_w)`. Per-item columns are identical
/// to [`im2col`] of that item, so a batched conv is the per-image convs
/// glued column-wise (the engine treats each item's column range as its
/// own noise-stream group).
pub fn im2col_batch(
    input: &BatchTensor,
    k: usize,
    stride: usize,
    pad: usize,
) -> (Vec<f64>, usize, usize) {
    assert_eq!(input.shape.len(), 3, "im2col_batch expects CHW items");
    let (c, h, w) = (input.shape[0], input.shape[1], input.shape[2]);
    let (out_h, out_w) = out_shape(h, w, k, stride, pad);
    let pos = out_h * out_w;
    let n_cols = input.batch * pos;
    let n_rows = c * k * k;
    let mut patches = vec![0.0f64; n_rows * n_cols];
    for ci in 0..c {
        for ki in 0..k {
            for kj in 0..k {
                let row = (ci * k + ki) * k + kj;
                let prow = &mut patches[row * n_cols..(row + 1) * n_cols];
                for (b, dst) in prow.chunks_exact_mut(pos).enumerate() {
                    fill_patch_row(
                        input.item(b),
                        h,
                        w,
                        ci,
                        ki,
                        kj,
                        stride,
                        pad,
                        out_h,
                        out_w,
                        dst,
                    );
                }
            }
        }
    }
    (patches, out_h, out_w)
}

fn out_shape(h: usize, w: usize, k: usize, stride: usize, pad: usize) -> (usize, usize) {
    assert!(h + 2 * pad >= k && w + 2 * pad >= k, "kernel larger than padded input");
    ((h + 2 * pad - k) / stride + 1, (w + 2 * pad - k) / stride + 1)
}

/// Fill one patch row (kernel tap `(ci, ki, kj)`) for one CHW item into
/// `dst` (`out_h·out_w` values, one per output pixel).
#[allow(clippy::too_many_arguments)]
#[inline]
fn fill_patch_row(
    item: &[f64],
    h: usize,
    w: usize,
    ci: usize,
    ki: usize,
    kj: usize,
    stride: usize,
    pad: usize,
    out_h: usize,
    out_w: usize,
    dst: &mut [f64],
) {
    let mut col = 0usize;
    for oy in 0..out_h {
        let iy = oy * stride + ki;
        for ox in 0..out_w {
            let ix = ox * stride + kj;
            // account for padding offset
            let v = if iy >= pad && ix >= pad && iy - pad < h && ix - pad < w {
                item[(ci * h + (iy - pad)) * w + (ix - pad)]
            } else {
                0.0
            };
            dst[col] = v;
            col += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1_kernel() {
        let t = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let (p, oh, ow) = im2col(&t, 1, 1, 0);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(p, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn same_conv_shape() {
        let t = Tensor::zeros(&[3, 8, 8]);
        let (p, oh, ow) = im2col(&t, 3, 1, 1);
        assert_eq!((oh, ow), (8, 8));
        assert_eq!(p.len(), 3 * 9 * 64);
    }

    #[test]
    fn stride_two_downsamples() {
        let t = Tensor::zeros(&[1, 8, 8]);
        let (_, oh, ow) = im2col(&t, 3, 2, 1);
        assert_eq!((oh, ow), (4, 4));
    }

    #[test]
    fn known_3x3_patch_values() {
        // 1 channel 3x3 input, 3x3 kernel, no pad -> single column = input
        let t = Tensor::from_vec(&[1, 3, 3], (1..=9).map(|x| x as f64).collect());
        let (p, oh, ow) = im2col(&t, 3, 1, 0);
        assert_eq!((oh, ow), (1, 1));
        assert_eq!(p, (1..=9).map(|x| x as f64).collect::<Vec<_>>());
    }

    #[test]
    fn padding_zeros_at_border() {
        let t = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let (p, oh, ow) = im2col(&t, 3, 1, 1);
        assert_eq!((oh, ow), (2, 2));
        // row 0 = kernel position (0,0): for output (0,0) that's input(-1,-1) = 0
        assert_eq!(p[0], 0.0);
        // center kernel position (1,1), output (0,0) -> input (0,0) = 1
        let row_center = (0 * 3 + 1) * 3 + 1;
        assert_eq!(p[row_center * 4], 1.0);
    }

    #[test]
    fn batched_im2col_is_per_item_im2col_glued_columnwise() {
        let mut rng = crate::util::XorShiftRng::new(41);
        let items: Vec<Tensor> = (0..3)
            .map(|_| {
                let mut data = vec![0.0; 2 * 5 * 5];
                rng.fill_uniform(&mut data, -1.0, 1.0);
                Tensor::from_vec(&[2, 5, 5], data)
            })
            .collect();
        let batch = BatchTensor::from_items(&items);
        let (pb, oh, ow) = im2col_batch(&batch, 3, 1, 1);
        assert_eq!((oh, ow), (5, 5));
        let pos = oh * ow;
        let n_cols = 3 * pos;
        for (b, item) in items.iter().enumerate() {
            let (pi, ih, iw) = im2col(item, 3, 1, 1);
            assert_eq!((ih, iw), (oh, ow));
            for row in 0..2 * 9 {
                let got = &pb[row * n_cols + b * pos..row * n_cols + (b + 1) * pos];
                let want = &pi[row * pos..(row + 1) * pos];
                assert_eq!(got, want, "item {b} row {row}");
            }
        }
    }

    #[test]
    fn batched_im2col_single_item_equals_im2col() {
        let t = Tensor::from_vec(&[1, 4, 4], (0..16).map(|x| x as f64).collect());
        let (p1, oh1, ow1) = im2col(&t, 3, 2, 1);
        let (pb, ohb, owb) = im2col_batch(&BatchTensor::from_items(&[t]), 3, 2, 1);
        assert_eq!((oh1, ow1), (ohb, owb));
        assert_eq!(p1, pb);
    }

    #[test]
    fn conv_via_im2col_matches_direct() {
        // direct 2d conv vs im2col + dot product
        let mut rng = crate::util::XorShiftRng::new(5);
        let mut data = vec![0.0; 2 * 5 * 5];
        rng.fill_uniform(&mut data, -1.0, 1.0);
        let input = Tensor::from_vec(&[2, 5, 5], data);
        let mut kern = vec![0.0; 2 * 3 * 3];
        rng.fill_uniform(&mut kern, -1.0, 1.0);
        let (p, oh, ow) = im2col(&input, 3, 1, 1);
        // im2col result for output channel 0
        let n_cols = oh * ow;
        let mut y = vec![0.0; n_cols];
        for r in 0..kern.len() {
            for col in 0..n_cols {
                y[col] += kern[r] * p[r * n_cols + col];
            }
        }
        // direct convolution at a few positions
        for (oy, ox) in [(0usize, 0usize), (2, 3), (4, 4)] {
            let mut acc = 0.0;
            for ci in 0..2 {
                for ki in 0..3 {
                    for kj in 0..3 {
                        let iy = oy as isize + ki as isize - 1;
                        let ix = ox as isize + kj as isize - 1;
                        if iy >= 0 && ix >= 0 && iy < 5 && ix < 5 {
                            acc += kern[(ci * 3 + ki) * 3 + kj]
                                * input.at3(ci, iy as usize, ix as usize);
                        }
                    }
                }
            }
            assert!((y[oy * ow + ox] - acc).abs() < 1e-12);
        }
    }
}

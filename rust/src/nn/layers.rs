//! Inference layers and the model container.
//!
//! Every matmul-bearing layer (conv, linear) funnels through the
//! [`MatmulEngine`](super::MatmulEngine), so the same model definition runs
//! exactly (reference) or photonically (digital twin with masks, noise and
//! energy accounting).
//!
//! Two execution modes share one model definition:
//!
//! * [`Model::forward`] — one image at a time (the batched path's
//!   equivalence oracle);
//! * [`Model::forward_batch`] — a whole batch per pass: every
//!   matmul-bearing layer issues ONE
//!   [`MatmulEngine::matmul_batch`](super::MatmulEngine::matmul_batch)
//!   with `n_cols = batch × positions` (item-major columns), and
//!   pool/relu/residual/flatten sweep the batch slab — the §3.2
//!   amortization (a programmed layer's cycle cost spread over many
//!   activation columns) realized in software.

use super::im2col::{im2col, im2col_batch};
use super::tensor::{BatchTensor, Tensor};
use super::MatmulEngine;

/// A layer of the inference graph.
#[derive(Debug, Clone)]
pub enum Layer {
    /// 2-D convolution; weight row-major `out_c × (in_c·k·k)`.
    Conv2d {
        name: String,
        out_c: usize,
        in_c: usize,
        k: usize,
        stride: usize,
        pad: usize,
        weight: Vec<f64>,
        bias: Vec<f64>,
    },
    /// Fully connected; weight `out × in`.
    Linear { name: String, out_dim: usize, in_dim: usize, weight: Vec<f64>, bias: Vec<f64> },
    /// Folded batch-norm: y = scale·x + shift, per channel.
    BatchNorm { scale: Vec<f64>, shift: Vec<f64> },
    Relu,
    /// Average pool k×k, stride k.
    AvgPool { k: usize },
    /// Max pool k×k, stride k.
    MaxPool { k: usize },
    /// Residual block: body layers + optional projection shortcut,
    /// output = relu(body(x) + shortcut(x)).
    Residual { body: Vec<Layer>, shortcut: Vec<Layer> },
    Flatten,
}

impl Layer {
    /// Matmul-bearing layers expose (name, weight, fan-out, fan-in).
    pub fn matmul_shape(&self) -> Option<(&str, usize, usize)> {
        match self {
            Layer::Conv2d { name, out_c, in_c, k, .. } => Some((name, *out_c, in_c * k * k)),
            Layer::Linear { name, out_dim, in_dim, .. } => Some((name, *out_dim, *in_dim)),
            _ => None,
        }
    }

    pub fn forward(&self, x: Tensor, engine: &mut dyn MatmulEngine) -> Tensor {
        match self {
            Layer::Conv2d { name, out_c, in_c, k, stride, pad, weight, bias } => {
                assert_eq!(x.shape[0], *in_c, "conv {name}: channel mismatch");
                let (patches, oh, ow) = im2col(&x, *k, *stride, *pad);
                let in_dim = in_c * k * k;
                let n_cols = oh * ow;
                let mut y = engine.matmul(name, weight, &patches, *out_c, in_dim, n_cols);
                for (o, b) in bias.iter().enumerate() {
                    for v in &mut y[o * n_cols..(o + 1) * n_cols] {
                        *v += b;
                    }
                }
                Tensor::from_vec(&[*out_c, oh, ow], y)
            }
            Layer::Linear { name, out_dim, in_dim, weight, bias } => {
                let n = x.numel();
                let x = if x.ndim() > 1 { x.reshape(&[n]) } else { x };
                assert_eq!(x.numel(), *in_dim, "linear {name}: input dim");
                let mut y = engine.matmul(name, weight, &x.data, *out_dim, *in_dim, 1);
                for (o, b) in bias.iter().enumerate() {
                    y[o] += b;
                }
                Tensor::from_vec(&[*out_dim], y)
            }
            Layer::BatchNorm { scale, shift } => {
                let c = x.shape[0];
                assert_eq!(scale.len(), c);
                let hw = x.numel() / c;
                let mut out = x;
                for ci in 0..c {
                    for v in &mut out.data[ci * hw..(ci + 1) * hw] {
                        *v = *v * scale[ci] + shift[ci];
                    }
                }
                out
            }
            Layer::Relu => x.map(|v| v.max(0.0)),
            Layer::AvgPool { k } => pool(x, *k, true),
            Layer::MaxPool { k } => pool(x, *k, false),
            Layer::Residual { body, shortcut } => {
                let mut main = x.clone();
                for l in body {
                    main = l.forward(main, engine);
                }
                let mut skip = x;
                for l in shortcut {
                    skip = l.forward(skip, engine);
                }
                main.add(&skip).map(|v| v.max(0.0))
            }
            Layer::Flatten => {
                let n = x.numel();
                x.reshape(&[n])
            }
        }
    }

    /// Batched forward: same math as [`Self::forward`] applied to every
    /// item, with each matmul-bearing layer issuing ONE
    /// [`MatmulEngine::matmul_batch`] over the item-major packed panel
    /// (`n_cols = batch × positions`) instead of `batch` engine passes.
    pub fn forward_batch(&self, x: BatchTensor, engine: &mut dyn MatmulEngine) -> BatchTensor {
        let bt = x.batch;
        match self {
            Layer::Conv2d { name, out_c, in_c, k, stride, pad, weight, bias } => {
                assert_eq!(x.shape[0], *in_c, "conv {name}: channel mismatch");
                let (patches, oh, ow) = im2col_batch(&x, *k, *stride, *pad);
                let in_dim = in_c * k * k;
                let pos = oh * ow;
                let y = engine.matmul_batch(name, weight, &patches, *out_c, in_dim, pos, bt);
                // un-pack the row-major `out_c × (batch·pos)` product into
                // the item-major batch slab, folding the bias in
                let mut out = BatchTensor::zeros(bt, &[*out_c, oh, ow]);
                for (o, b_o) in bias.iter().enumerate() {
                    let yrow = &y[o * bt * pos..(o + 1) * bt * pos];
                    for b in 0..bt {
                        let dst =
                            &mut out.data[(b * out_c + o) * pos..(b * out_c + o + 1) * pos];
                        for (d, &v) in dst.iter_mut().zip(&yrow[b * pos..(b + 1) * pos]) {
                            *d = v + b_o;
                        }
                    }
                }
                out
            }
            Layer::Linear { name, out_dim, in_dim, weight, bias } => {
                assert_eq!(x.item_len(), *in_dim, "linear {name}: input dim");
                // transpose the item-major slab into the `in_dim × batch`
                // panel (one column per item; cols_per_item = 1)
                let mut xm = vec![0.0f64; in_dim * bt];
                for b in 0..bt {
                    for (j, &v) in x.item(b).iter().enumerate() {
                        xm[j * bt + b] = v;
                    }
                }
                let y = engine.matmul_batch(name, weight, &xm, *out_dim, *in_dim, 1, bt);
                let mut out = BatchTensor::zeros(bt, &[*out_dim]);
                for (o, b_o) in bias.iter().enumerate() {
                    for b in 0..bt {
                        out.data[b * out_dim + o] = y[o * bt + b] + b_o;
                    }
                }
                out
            }
            Layer::BatchNorm { scale, shift } => {
                let c = x.shape[0];
                assert_eq!(scale.len(), c);
                let hw = x.item_len() / c;
                let mut out = x;
                for item in out.data.chunks_exact_mut(c * hw) {
                    for ci in 0..c {
                        for v in &mut item[ci * hw..(ci + 1) * hw] {
                            *v = *v * scale[ci] + shift[ci];
                        }
                    }
                }
                out
            }
            Layer::Relu => x.map(|v| v.max(0.0)),
            Layer::AvgPool { k } => pool_batch(x, *k, true),
            Layer::MaxPool { k } => pool_batch(x, *k, false),
            Layer::Residual { body, shortcut } => {
                let mut main = x.clone();
                for l in body {
                    main = l.forward_batch(main, engine);
                }
                let mut skip = x;
                for l in shortcut {
                    skip = l.forward_batch(skip, engine);
                }
                main.add(&skip).map(|v| v.max(0.0))
            }
            Layer::Flatten => {
                let n = x.item_len();
                x.reshape_items(&[n])
            }
        }
    }
}

fn pool(x: Tensor, k: usize, avg: bool) -> Tensor {
    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let (oh, ow) = (h / k, w / k);
    assert!(oh > 0 && ow > 0, "pool window larger than input");
    let mut out = Tensor::zeros(&[c, oh, ow]);
    pool_item(&x.data, c, h, w, k, avg, &mut out.data);
    out
}

fn pool_batch(x: BatchTensor, k: usize, avg: bool) -> BatchTensor {
    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let (oh, ow) = (h / k, w / k);
    assert!(oh > 0 && ow > 0, "pool window larger than input");
    let mut out = BatchTensor::zeros(x.batch, &[c, oh, ow]);
    for (src, dst) in
        x.data.chunks_exact(c * h * w).zip(out.data.chunks_exact_mut(c * oh * ow))
    {
        pool_item(src, c, h, w, k, avg, dst);
    }
    out
}

/// k×k stride-k pooling of one CHW item (`dst` is `c × (h/k) × (w/k)`).
fn pool_item(src: &[f64], c: usize, h: usize, w: usize, k: usize, avg: bool, dst: &mut [f64]) {
    let (oh, ow) = (h / k, w / k);
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = if avg { 0.0 } else { f64::NEG_INFINITY };
                for dy in 0..k {
                    for dx in 0..k {
                        let v = src[(ci * h + oy * k + dy) * w + ox * k + dx];
                        if avg {
                            acc += v;
                        } else if v > acc {
                            acc = v;
                        }
                    }
                }
                if avg {
                    acc /= (k * k) as f64;
                }
                dst[(ci * oh + oy) * ow + ox] = acc;
            }
        }
    }
}

/// A sequential model with a name and input shape.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub layers: Vec<Layer>,
}

impl Model {
    pub fn forward(&self, x: Tensor, engine: &mut dyn MatmulEngine) -> Tensor {
        assert_eq!(x.shape, self.input_shape, "model {} input shape", self.name);
        let mut cur = x;
        for l in &self.layers {
            cur = l.forward(cur, engine);
        }
        cur
    }

    /// Predicted class.
    pub fn predict(&self, x: Tensor, engine: &mut dyn MatmulEngine) -> usize {
        self.forward(x, engine).argmax()
    }

    /// Batched forward: carry `images` through the whole model in ONE
    /// engine pass per layer (`n_cols = batch × positions`), returning
    /// per-image outputs in input order.
    ///
    /// Value-identical to `batch` sequential [`Self::forward`] calls on
    /// the same engine state — including PD noise: the engine is told the
    /// batch geometry via [`MatmulEngine::begin_batch`] (`batch`, matmul
    /// calls per item) so its counter-based noise streams address each
    /// item's columns exactly as the sequential schedule would
    /// (`rust/tests/batch_forward.rs` asserts bit-equality).
    pub fn forward_batch(
        &self,
        images: Vec<Tensor>,
        engine: &mut dyn MatmulEngine,
    ) -> Vec<Tensor> {
        if images.is_empty() {
            return Vec::new();
        }
        for x in &images {
            assert_eq!(x.shape, self.input_shape, "model {} input shape", self.name);
        }
        let batch = images.len();
        let mut cur = BatchTensor::from_items(&images);
        drop(images);
        engine.begin_batch(batch, self.matmul_layer_count() as u64);
        for l in &self.layers {
            cur = l.forward_batch(cur, engine);
        }
        engine.end_batch();
        cur.into_items()
    }

    /// Number of *epoch-consuming* matmul calls per forward, counted
    /// without materializing names — this runs once per served shard
    /// ([`Self::forward_batch`] passes it to
    /// [`MatmulEngine::begin_batch`] as the per-item stride).
    ///
    /// Degenerate (zero-dim) layers are excluded: their engine call
    /// returns early without consuming a noise epoch in sequential
    /// execution, so counting them would shift every later item's
    /// streams and break batched-vs-sequential bit identity
    /// (`rust/tests/batch_forward.rs`). [`Self::matmul_layers`] still
    /// lists them (masking/protection care about existence, not epochs).
    pub fn matmul_layer_count(&self) -> usize {
        fn walk(layers: &[Layer]) -> usize {
            layers
                .iter()
                .map(|l| {
                    usize::from(l.matmul_shape().is_some_and(|(_, o, i)| o > 0 && i > 0))
                        + match l {
                            Layer::Residual { body, shortcut } => {
                                walk(body) + walk(shortcut)
                            }
                            _ => 0,
                        }
                })
                .sum()
        }
        walk(&self.layers)
    }

    /// All matmul layers, flattened through residual blocks:
    /// (name, out_dim, in_dim).
    pub fn matmul_layers(&self) -> Vec<(String, usize, usize)> {
        fn walk(layers: &[Layer], out: &mut Vec<(String, usize, usize)>) {
            for l in layers {
                if let Some((n, o, i)) = l.matmul_shape() {
                    out.push((n.to_string(), o, i));
                }
                if let Layer::Residual { body, shortcut } = l {
                    walk(body, out);
                    walk(shortcut, out);
                }
            }
        }
        let mut out = Vec::new();
        walk(&self.layers, &mut out);
        out
    }

    /// Visit every matmul layer's weights mutably (for loading / masking).
    pub fn visit_weights_mut(&mut self, mut f: impl FnMut(&str, &mut Vec<f64>, &mut Vec<f64>)) {
        fn walk(
            layers: &mut [Layer],
            f: &mut impl FnMut(&str, &mut Vec<f64>, &mut Vec<f64>),
        ) {
            for l in layers.iter_mut() {
                match l {
                    Layer::Conv2d { name, weight, bias, .. }
                    | Layer::Linear { name, weight, bias, .. } => {
                        let n = name.clone();
                        f(&n, weight, bias);
                    }
                    Layer::Residual { body, shortcut } => {
                        walk(body, f);
                        walk(shortcut, f);
                    }
                    _ => {}
                }
            }
        }
        walk(&mut self.layers, &mut f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ExactEngine;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weight passes through
        let l = Layer::Conv2d {
            name: "c".into(),
            out_c: 1,
            in_c: 1,
            k: 1,
            stride: 1,
            pad: 0,
            weight: vec![1.0],
            bias: vec![0.0],
        };
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0, -2.0, 3.0, 4.0]);
        let y = l.forward(x.clone(), &mut ExactEngine);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn relu_clamps() {
        let y = Layer::Relu.forward(
            Tensor::from_vec(&[1, 1, 2], vec![-1.0, 2.0]),
            &mut ExactEngine,
        );
        assert_eq!(y.data, vec![0.0, 2.0]);
    }

    #[test]
    fn avgpool_and_maxpool() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let a = Layer::AvgPool { k: 2 }.forward(x.clone(), &mut ExactEngine);
        assert_eq!(a.data, vec![2.5]);
        let m = Layer::MaxPool { k: 2 }.forward(x, &mut ExactEngine);
        assert_eq!(m.data, vec![4.0]);
    }

    #[test]
    fn linear_with_bias() {
        let l = Layer::Linear {
            name: "fc".into(),
            out_dim: 2,
            in_dim: 2,
            weight: vec![1.0, 0.0, 0.0, 1.0],
            bias: vec![0.5, -0.5],
        };
        let y = l.forward(Tensor::from_vec(&[2], vec![1.0, 2.0]), &mut ExactEngine);
        assert_eq!(y.data, vec![1.5, 1.5]);
    }

    #[test]
    fn residual_identity_shortcut() {
        // body = 0-weight conv -> relu(0 + x) = relu(x)
        let body = vec![Layer::Conv2d {
            name: "rb".into(),
            out_c: 1,
            in_c: 1,
            k: 1,
            stride: 1,
            pad: 0,
            weight: vec![0.0],
            bias: vec![0.0],
        }];
        let l = Layer::Residual { body, shortcut: vec![] };
        let x = Tensor::from_vec(&[1, 1, 2], vec![-3.0, 5.0]);
        let y = l.forward(x, &mut ExactEngine);
        assert_eq!(y.data, vec![0.0, 5.0]);
    }

    #[test]
    fn batchnorm_per_channel() {
        let l = Layer::BatchNorm { scale: vec![2.0, 0.5], shift: vec![1.0, 0.0] };
        let x = Tensor::from_vec(&[2, 1, 2], vec![1.0, 2.0, 4.0, 8.0]);
        let y = l.forward(x, &mut ExactEngine);
        assert_eq!(y.data, vec![3.0, 5.0, 2.0, 4.0]);
    }

    #[test]
    fn model_matmul_layer_listing() {
        let m = crate::nn::models::cnn3();
        let names: Vec<String> = m.matmul_layers().iter().map(|(n, _, _)| n.clone()).collect();
        assert_eq!(names, vec!["conv1", "conv2", "fc"]);
    }

    /// Every layer kind (conv, linear, pools, batchnorm, residual,
    /// flatten, relu) batched over B items must be bit-identical to B
    /// sequential forwards on the exact engine.
    #[test]
    fn forward_batch_bit_identical_to_sequential_on_exact_engine() {
        let mut rng = crate::util::XorShiftRng::new(0xBA7C);
        let mk = |rng: &mut crate::util::XorShiftRng, n: usize| {
            let mut v = vec![0.0; n];
            rng.fill_uniform(&mut v, -1.0, 1.0);
            v
        };
        let w1 = mk(&mut rng, 4 * 2 * 9);
        let wr = mk(&mut rng, 4 * 4 * 9);
        let wl = mk(&mut rng, 5 * 16);
        let model = Model {
            name: "mixed".into(),
            input_shape: vec![2, 8, 8],
            layers: vec![
                Layer::Conv2d {
                    name: "c1".into(),
                    out_c: 4,
                    in_c: 2,
                    k: 3,
                    stride: 1,
                    pad: 1,
                    weight: w1,
                    bias: vec![0.1, -0.2, 0.3, 0.0],
                },
                Layer::BatchNorm {
                    scale: vec![1.5, 0.5, 2.0, 1.0],
                    shift: vec![0.0, 0.1, -0.1, 0.2],
                },
                Layer::Relu,
                Layer::Residual {
                    body: vec![Layer::Conv2d {
                        name: "rb".into(),
                        out_c: 4,
                        in_c: 4,
                        k: 3,
                        stride: 1,
                        pad: 1,
                        weight: wr,
                        bias: vec![0.0; 4],
                    }],
                    shortcut: vec![],
                },
                Layer::MaxPool { k: 2 },
                Layer::AvgPool { k: 2 },
                Layer::Flatten,
                Layer::Linear {
                    name: "fc".into(),
                    out_dim: 5,
                    in_dim: 16,
                    weight: wl,
                    bias: vec![0.5, -0.5, 0.0, 0.25, -0.25],
                },
            ],
        };
        for b in [1usize, 2, 5] {
            let images: Vec<Tensor> = (0..b)
                .map(|_| {
                    let mut v = vec![0.0; 2 * 8 * 8];
                    rng.fill_uniform(&mut v, 0.0, 1.0);
                    Tensor::from_vec(&[2, 8, 8], v)
                })
                .collect();
            let batched = model.forward_batch(images.clone(), &mut crate::nn::ExactEngine);
            for (i, img) in images.into_iter().enumerate() {
                let seq = model.forward(img, &mut crate::nn::ExactEngine);
                assert_eq!(batched[i], seq, "B={b} item {i}");
            }
        }
    }

    #[test]
    fn forward_batch_of_empty_input_is_empty() {
        let m = crate::nn::models::cnn3();
        assert!(m.forward_batch(Vec::new(), &mut crate::nn::ExactEngine).is_empty());
    }

    #[test]
    fn matmul_layer_count_matches_listing() {
        for m in [
            crate::nn::models::cnn3(),
            crate::nn::models::mlp(),
            crate::nn::models::resnet18(),
        ] {
            assert_eq!(m.matmul_layer_count(), m.matmul_layers().len(), "{}", m.name);
        }
    }
}

//! Inference layers and the model container.
//!
//! Every matmul-bearing layer (conv, linear) funnels through the
//! [`MatmulEngine`](super::MatmulEngine), so the same model definition runs
//! exactly (reference) or photonically (digital twin with masks, noise and
//! energy accounting).

use super::im2col::im2col;
use super::tensor::Tensor;
use super::MatmulEngine;

/// A layer of the inference graph.
#[derive(Debug, Clone)]
pub enum Layer {
    /// 2-D convolution; weight row-major `out_c × (in_c·k·k)`.
    Conv2d {
        name: String,
        out_c: usize,
        in_c: usize,
        k: usize,
        stride: usize,
        pad: usize,
        weight: Vec<f64>,
        bias: Vec<f64>,
    },
    /// Fully connected; weight `out × in`.
    Linear { name: String, out_dim: usize, in_dim: usize, weight: Vec<f64>, bias: Vec<f64> },
    /// Folded batch-norm: y = scale·x + shift, per channel.
    BatchNorm { scale: Vec<f64>, shift: Vec<f64> },
    Relu,
    /// Average pool k×k, stride k.
    AvgPool { k: usize },
    /// Max pool k×k, stride k.
    MaxPool { k: usize },
    /// Residual block: body layers + optional projection shortcut,
    /// output = relu(body(x) + shortcut(x)).
    Residual { body: Vec<Layer>, shortcut: Vec<Layer> },
    Flatten,
}

impl Layer {
    /// Matmul-bearing layers expose (name, weight, fan-out, fan-in).
    pub fn matmul_shape(&self) -> Option<(&str, usize, usize)> {
        match self {
            Layer::Conv2d { name, out_c, in_c, k, .. } => Some((name, *out_c, in_c * k * k)),
            Layer::Linear { name, out_dim, in_dim, .. } => Some((name, *out_dim, *in_dim)),
            _ => None,
        }
    }

    pub fn forward(&self, x: Tensor, engine: &mut dyn MatmulEngine) -> Tensor {
        match self {
            Layer::Conv2d { name, out_c, in_c, k, stride, pad, weight, bias } => {
                assert_eq!(x.shape[0], *in_c, "conv {name}: channel mismatch");
                let (patches, oh, ow) = im2col(&x, *k, *stride, *pad);
                let in_dim = in_c * k * k;
                let n_cols = oh * ow;
                let mut y = engine.matmul(name, weight, &patches, *out_c, in_dim, n_cols);
                for (o, b) in bias.iter().enumerate() {
                    for v in &mut y[o * n_cols..(o + 1) * n_cols] {
                        *v += b;
                    }
                }
                Tensor::from_vec(&[*out_c, oh, ow], y)
            }
            Layer::Linear { name, out_dim, in_dim, weight, bias } => {
                let n = x.numel();
                let x = if x.ndim() > 1 { x.reshape(&[n]) } else { x };
                assert_eq!(x.numel(), *in_dim, "linear {name}: input dim");
                let mut y = engine.matmul(name, weight, &x.data, *out_dim, *in_dim, 1);
                for (o, b) in bias.iter().enumerate() {
                    y[o] += b;
                }
                Tensor::from_vec(&[*out_dim], y)
            }
            Layer::BatchNorm { scale, shift } => {
                let c = x.shape[0];
                assert_eq!(scale.len(), c);
                let hw = x.numel() / c;
                let mut out = x;
                for ci in 0..c {
                    for v in &mut out.data[ci * hw..(ci + 1) * hw] {
                        *v = *v * scale[ci] + shift[ci];
                    }
                }
                out
            }
            Layer::Relu => x.map(|v| v.max(0.0)),
            Layer::AvgPool { k } => pool(x, *k, true),
            Layer::MaxPool { k } => pool(x, *k, false),
            Layer::Residual { body, shortcut } => {
                let mut main = x.clone();
                for l in body {
                    main = l.forward(main, engine);
                }
                let mut skip = x;
                for l in shortcut {
                    skip = l.forward(skip, engine);
                }
                main.add(&skip).map(|v| v.max(0.0))
            }
            Layer::Flatten => {
                let n = x.numel();
                x.reshape(&[n])
            }
        }
    }
}

fn pool(x: Tensor, k: usize, avg: bool) -> Tensor {
    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let (oh, ow) = (h / k, w / k);
    assert!(oh > 0 && ow > 0, "pool window larger than input");
    let mut out = Tensor::zeros(&[c, oh, ow]);
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = if avg { 0.0 } else { f64::NEG_INFINITY };
                for dy in 0..k {
                    for dx in 0..k {
                        let v = x.at3(ci, oy * k + dy, ox * k + dx);
                        if avg {
                            acc += v;
                        } else if v > acc {
                            acc = v;
                        }
                    }
                }
                if avg {
                    acc /= (k * k) as f64;
                }
                out.set3(ci, oy, ox, acc);
            }
        }
    }
    out
}

/// A sequential model with a name and input shape.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub layers: Vec<Layer>,
}

impl Model {
    pub fn forward(&self, x: Tensor, engine: &mut dyn MatmulEngine) -> Tensor {
        assert_eq!(x.shape, self.input_shape, "model {} input shape", self.name);
        let mut cur = x;
        for l in &self.layers {
            cur = l.forward(cur, engine);
        }
        cur
    }

    /// Predicted class.
    pub fn predict(&self, x: Tensor, engine: &mut dyn MatmulEngine) -> usize {
        self.forward(x, engine).argmax()
    }

    /// All matmul layers, flattened through residual blocks:
    /// (name, out_dim, in_dim).
    pub fn matmul_layers(&self) -> Vec<(String, usize, usize)> {
        fn walk(layers: &[Layer], out: &mut Vec<(String, usize, usize)>) {
            for l in layers {
                if let Some((n, o, i)) = l.matmul_shape() {
                    out.push((n.to_string(), o, i));
                }
                if let Layer::Residual { body, shortcut } = l {
                    walk(body, out);
                    walk(shortcut, out);
                }
            }
        }
        let mut out = Vec::new();
        walk(&self.layers, &mut out);
        out
    }

    /// Visit every matmul layer's weights mutably (for loading / masking).
    pub fn visit_weights_mut(&mut self, mut f: impl FnMut(&str, &mut Vec<f64>, &mut Vec<f64>)) {
        fn walk(
            layers: &mut [Layer],
            f: &mut impl FnMut(&str, &mut Vec<f64>, &mut Vec<f64>),
        ) {
            for l in layers.iter_mut() {
                match l {
                    Layer::Conv2d { name, weight, bias, .. }
                    | Layer::Linear { name, weight, bias, .. } => {
                        let n = name.clone();
                        f(&n, weight, bias);
                    }
                    Layer::Residual { body, shortcut } => {
                        walk(body, f);
                        walk(shortcut, f);
                    }
                    _ => {}
                }
            }
        }
        walk(&mut self.layers, &mut f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ExactEngine;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weight passes through
        let l = Layer::Conv2d {
            name: "c".into(),
            out_c: 1,
            in_c: 1,
            k: 1,
            stride: 1,
            pad: 0,
            weight: vec![1.0],
            bias: vec![0.0],
        };
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0, -2.0, 3.0, 4.0]);
        let y = l.forward(x.clone(), &mut ExactEngine);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn relu_clamps() {
        let y = Layer::Relu.forward(
            Tensor::from_vec(&[1, 1, 2], vec![-1.0, 2.0]),
            &mut ExactEngine,
        );
        assert_eq!(y.data, vec![0.0, 2.0]);
    }

    #[test]
    fn avgpool_and_maxpool() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let a = Layer::AvgPool { k: 2 }.forward(x.clone(), &mut ExactEngine);
        assert_eq!(a.data, vec![2.5]);
        let m = Layer::MaxPool { k: 2 }.forward(x, &mut ExactEngine);
        assert_eq!(m.data, vec![4.0]);
    }

    #[test]
    fn linear_with_bias() {
        let l = Layer::Linear {
            name: "fc".into(),
            out_dim: 2,
            in_dim: 2,
            weight: vec![1.0, 0.0, 0.0, 1.0],
            bias: vec![0.5, -0.5],
        };
        let y = l.forward(Tensor::from_vec(&[2], vec![1.0, 2.0]), &mut ExactEngine);
        assert_eq!(y.data, vec![1.5, 1.5]);
    }

    #[test]
    fn residual_identity_shortcut() {
        // body = 0-weight conv -> relu(0 + x) = relu(x)
        let body = vec![Layer::Conv2d {
            name: "rb".into(),
            out_c: 1,
            in_c: 1,
            k: 1,
            stride: 1,
            pad: 0,
            weight: vec![0.0],
            bias: vec![0.0],
        }];
        let l = Layer::Residual { body, shortcut: vec![] };
        let x = Tensor::from_vec(&[1, 1, 2], vec![-3.0, 5.0]);
        let y = l.forward(x, &mut ExactEngine);
        assert_eq!(y.data, vec![0.0, 5.0]);
    }

    #[test]
    fn batchnorm_per_channel() {
        let l = Layer::BatchNorm { scale: vec![2.0, 0.5], shift: vec![1.0, 0.0] };
        let x = Tensor::from_vec(&[2, 1, 2], vec![1.0, 2.0, 4.0, 8.0]);
        let y = l.forward(x, &mut ExactEngine);
        assert_eq!(y.data, vec![3.0, 5.0, 2.0, 4.0]);
    }

    #[test]
    fn model_matmul_layer_listing() {
        let m = crate::nn::models::cnn3();
        let names: Vec<String> = m.matmul_layers().iter().map(|(n, _, _)| n.clone()).collect();
        assert_eq!(names, vec!["conv1", "conv2", "fc"]);
    }
}

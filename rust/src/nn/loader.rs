//! Load python-trained weight/mask bundles.
//!
//! `python/compile/dst.py` exports, per model, a directory containing
//! `weights.json` — `{layer: {"w": [...], "b": [...]}}` — and
//! `masks.json` — `{layer: {"p":..,"q":..,"chunks":[{"row":[..],"col":[..]},..]}}`.
//! JSON keeps the bundle human-inspectable; sizes here are small (<50 MB).

use crate::nn::Model;
use crate::sparsity::LayerMask;
use crate::util::Json;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Parsed weight bundle.
#[derive(Debug, Default)]
pub struct WeightBundle {
    pub weights: BTreeMap<String, (Vec<f64>, Vec<f64>)>,
}

impl WeightBundle {
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).map_err(Error::Serde)?;
        let obj = match v {
            Json::Obj(m) => m,
            _ => return Err(Error::Serde("weights.json must be an object".into())),
        };
        let mut weights = BTreeMap::new();
        for (name, entry) in obj {
            let w = entry
                .get("w")
                .and_then(Json::f64_vec)
                .ok_or_else(|| Error::Serde(format!("layer {name}: missing 'w'")))?;
            // a bundle may omit 'b' (bias-free layer), but a present,
            // malformed 'b' must error — not decay into "no bias"
            let b = match entry.get("b") {
                None => Vec::new(),
                Some(v) => v.f64_vec().ok_or_else(|| {
                    Error::Serde(format!("layer {name}: malformed 'b'"))
                })?,
            };
            weights.insert(name, (w, b));
        }
        Ok(Self { weights })
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Install into a model; layers missing from the bundle keep their
    /// random init. Returns the number of layers loaded.
    pub fn install(&self, model: &mut Model) -> usize {
        let mut n = 0;
        model.visit_weights_mut(|name, w, b| {
            if let Some((nw, nb)) = self.weights.get(name) {
                assert_eq!(nw.len(), w.len(), "layer {name}: weight size mismatch");
                w.copy_from_slice(nw);
                if !nb.is_empty() {
                    assert_eq!(nb.len(), b.len(), "layer {name}: bias size mismatch");
                    b.copy_from_slice(nb);
                }
                n += 1;
            }
        });
        n
    }
}

/// Parse a masks.json bundle into per-layer masks.
pub fn parse_masks(text: &str) -> Result<BTreeMap<String, LayerMask>> {
    let v = Json::parse(text).map_err(Error::Serde)?;
    let obj = match v {
        Json::Obj(m) => m,
        _ => return Err(Error::Serde("masks.json must be an object".into())),
    };
    let mut out = BTreeMap::new();
    for (name, entry) in obj {
        out.insert(name, LayerMask::from_json(&entry)?);
    }
    Ok(out)
}

pub fn load_masks(path: &Path) -> Result<BTreeMap<String, LayerMask>> {
    parse_masks(&std::fs::read_to_string(path)?)
}

/// Write a masks bundle (used by the rust-side DST refinement and tests).
pub fn masks_to_json(masks: &BTreeMap<String, LayerMask>) -> String {
    let obj: BTreeMap<String, Json> =
        masks.iter().map(|(k, v)| (k.clone(), v.to_json())).collect();
    Json::Obj(obj).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::ChunkMask;

    #[test]
    fn parse_and_install_weights() {
        let mut model = crate::nn::models::cnn3();
        let shapes = model.matmul_layers();
        let (name, o, i) = shapes[0].clone();
        let text = format!(
            "{{\"{name}\": {{\"w\": [{}], \"b\": [{}]}}}}",
            vec!["0.5"; o * i].join(","),
            vec!["0.1"; o].join(","),
        );
        let bundle = WeightBundle::parse(&text).unwrap();
        assert_eq!(bundle.install(&mut model), 1);
        model.visit_weights_mut(|n, w, b| {
            if n == name {
                assert!(w.iter().all(|&x| x == 0.5));
                assert!(b.iter().all(|&x| x == 0.1));
            }
        });
    }

    #[test]
    fn masks_roundtrip() {
        let mut masks = BTreeMap::new();
        masks.insert(
            "conv1".to_string(),
            LayerMask {
                p: 1,
                q: 2,
                chunks: vec![
                    ChunkMask::new(vec![true, false], vec![true, true]),
                    ChunkMask::new(vec![false, true], vec![false, true]),
                ],
            },
        );
        let s = masks_to_json(&masks);
        let back = parse_masks(&s).unwrap();
        assert_eq!(back["conv1"].chunks, masks["conv1"].chunks);
    }

    #[test]
    fn rejects_malformed() {
        assert!(WeightBundle::parse("[1,2]").is_err());
        assert!(parse_masks("{\"l\": {\"p\":1,\"q\":1}}").is_err());
    }

    #[test]
    fn corrupt_weight_element_is_an_error_not_a_short_tensor() {
        // strict Json::f64_vec: one bad element fails the whole bundle
        // instead of decoding a wrong-length weight vector
        let text = "{\"fc\": {\"w\": [0.5, \"oops\", 0.5]}}";
        assert!(WeightBundle::parse(text).is_err());
        // a malformed present 'b' errors too (it must not silently
        // decay into "bundle has no bias")
        let text = "{\"fc\": {\"w\": [0.5], \"b\": [0.1, \"oops\"]}}";
        assert!(WeightBundle::parse(text).is_err());
        // while an absent 'b' stays legal
        let text = "{\"fc\": {\"w\": [0.5]}}";
        assert!(WeightBundle::parse(text).is_ok());
        // same for masks: a malformed bool no longer coerces to false
        let masks = "{\"l\": {\"p\":1,\"q\":1,\"chunks\":[{\"row\":[true,null],\"col\":[1,0]}]}}";
        assert!(parse_masks(masks).is_err());
    }
}

//! A tiny row-major f64 tensor — just enough for CNN inference.

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f64>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f64>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Reshape in place (must preserve element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// CHW accessor for 3-D tensors.
    #[inline]
    pub fn at3(&self, c: usize, h: usize, w: usize) -> f64 {
        debug_assert_eq!(self.ndim(), 3);
        let (_, hh, ww) = (self.shape[0], self.shape[1], self.shape[2]);
        self.data[(c * hh + h) * ww + w]
    }

    #[inline]
    pub fn set3(&mut self, c: usize, h: usize, w: usize, v: f64) {
        let (_, hh, ww) = (self.shape[0], self.shape[1], self.shape[2]);
        self.data[(c * hh + h) * ww + w] = v;
    }

    pub fn map(mut self, f: impl Fn(f64) -> f64) -> Self {
        for v in self.data.iter_mut() {
            *v = f(*v);
        }
        self
    }

    /// Elementwise add (shapes must match) — used for residual connections.
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape, "residual shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn argmax(&self) -> usize {
        self.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set3(1, 2, 3, 5.0);
        assert_eq!(t.at3(1, 2, 3), 5.0);
        assert_eq!(t.numel(), 24);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).reshape(&[4]);
        assert_eq!(t.shape, vec![4]);
        assert_eq!(t.data[3], 4.0);
    }

    #[test]
    fn residual_add() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![0.5, 0.5]);
        assert_eq!(a.add(&b).data, vec![1.5, 2.5]);
    }

    #[test]
    fn argmax_picks_largest() {
        let t = Tensor::from_vec(&[4], vec![0.1, 3.0, -1.0, 2.0]);
        assert_eq!(t.argmax(), 1);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        let _ = Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}

//! A tiny row-major f64 tensor — just enough for CNN inference — and
//! its batched sibling [`BatchTensor`], the activation representation
//! the batched-compute serving path streams through the engine (one
//! `MatmulEngine::matmul_batch` per layer with `n_cols = batch ×
//! positions`, instead of one engine pass per image).

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f64>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f64>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Reshape in place (must preserve element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// CHW accessor for 3-D tensors.
    #[inline]
    pub fn at3(&self, c: usize, h: usize, w: usize) -> f64 {
        debug_assert_eq!(self.ndim(), 3);
        let (_, hh, ww) = (self.shape[0], self.shape[1], self.shape[2]);
        self.data[(c * hh + h) * ww + w]
    }

    #[inline]
    pub fn set3(&mut self, c: usize, h: usize, w: usize, v: f64) {
        let (_, hh, ww) = (self.shape[0], self.shape[1], self.shape[2]);
        self.data[(c * hh + h) * ww + w] = v;
    }

    pub fn map(mut self, f: impl Fn(f64) -> f64) -> Self {
        for v in self.data.iter_mut() {
            *v = f(*v);
        }
        self
    }

    /// Elementwise add (shapes must match) — used for residual connections.
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape, "residual shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn argmax(&self) -> usize {
        self.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// A batch of same-shaped activations, stored **item-major**: item `b`
/// occupies `data[b·item_len .. (b+1)·item_len]`, each item laid out
/// exactly like the corresponding [`Tensor`]. This is the activation
/// representation of the batched forward path
/// ([`Model::forward_batch`](super::Model::forward_batch)): elementwise
/// layers sweep the flat slab once, and matmul-bearing layers lower the
/// whole batch into a single `in_dim × (batch·cols_per_item)` panel with
/// item-major columns (item `b`'s columns at `[b·cols_per_item,
/// (b+1)·cols_per_item)`) — the column-offset convention the engine's
/// counter-based noise streams key on (see
/// [`MatmulEngine::matmul_batch`](super::MatmulEngine::matmul_batch)).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchTensor {
    pub batch: usize,
    /// Per-item shape (shared by every item).
    pub shape: Vec<usize>,
    /// Item-major flat storage, `batch · item_len` values.
    pub data: Vec<f64>,
}

impl BatchTensor {
    pub fn zeros(batch: usize, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { batch, shape: shape.to_vec(), data: vec![0.0; batch * n] }
    }

    /// Pack same-shaped tensors into one batch (item order preserved).
    pub fn from_items(items: &[Tensor]) -> Self {
        assert!(!items.is_empty(), "empty batch");
        let shape = items[0].shape.clone();
        let n = items[0].numel();
        let mut data = Vec::with_capacity(items.len() * n);
        for t in items {
            assert_eq!(t.shape, shape, "batch items must share one shape");
            data.extend_from_slice(&t.data);
        }
        Self { batch: items.len(), shape, data }
    }

    /// Split back into per-item tensors (inverse of [`Self::from_items`]).
    pub fn into_items(self) -> Vec<Tensor> {
        let n = self.item_len();
        let mut out = Vec::with_capacity(self.batch);
        let mut data = self.data;
        for b in (0..self.batch).rev() {
            let tail = data.split_off(b * n);
            out.push(Tensor { shape: self.shape.clone(), data: tail });
        }
        out.reverse();
        out
    }

    /// Elements per item.
    pub fn item_len(&self) -> usize {
        self.shape.iter().product()
    }

    /// Item `b`'s flat values.
    #[inline]
    pub fn item(&self, b: usize) -> &[f64] {
        let n = self.item_len();
        &self.data[b * n..(b + 1) * n]
    }

    /// Replace the per-item shape (must preserve the per-item count).
    pub fn reshape_items(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.item_len());
        self.shape = shape.to_vec();
        self
    }

    /// Elementwise map over the whole batch.
    pub fn map(mut self, f: impl Fn(f64) -> f64) -> Self {
        for v in self.data.iter_mut() {
            *v = f(*v);
        }
        self
    }

    /// Elementwise add (batch and shapes must match) — batched residual.
    pub fn add(&self, other: &BatchTensor) -> BatchTensor {
        assert_eq!(self.batch, other.batch, "residual batch mismatch");
        assert_eq!(self.shape, other.shape, "residual shape mismatch");
        BatchTensor {
            batch: self.batch,
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set3(1, 2, 3, 5.0);
        assert_eq!(t.at3(1, 2, 3), 5.0);
        assert_eq!(t.numel(), 24);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).reshape(&[4]);
        assert_eq!(t.shape, vec![4]);
        assert_eq!(t.data[3], 4.0);
    }

    #[test]
    fn residual_add() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![0.5, 0.5]);
        assert_eq!(a.add(&b).data, vec![1.5, 2.5]);
    }

    #[test]
    fn argmax_picks_largest() {
        let t = Tensor::from_vec(&[4], vec![0.1, 3.0, -1.0, 2.0]);
        assert_eq!(t.argmax(), 1);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        let _ = Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn batch_roundtrip_preserves_items() {
        let items = vec![
            Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            Tensor::from_vec(&[1, 2, 2], vec![5.0, 6.0, 7.0, 8.0]),
            Tensor::from_vec(&[1, 2, 2], vec![-1.0, 0.0, 0.5, 9.0]),
        ];
        let b = BatchTensor::from_items(&items);
        assert_eq!(b.batch, 3);
        assert_eq!(b.item(1), &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(b.into_items(), items);
    }

    #[test]
    fn batch_map_add_and_reshape() {
        let a = BatchTensor::from_items(&[
            Tensor::from_vec(&[2], vec![1.0, -2.0]),
            Tensor::from_vec(&[2], vec![3.0, 4.0]),
        ]);
        let relu = a.clone().map(|v| v.max(0.0));
        assert_eq!(relu.data, vec![1.0, 0.0, 3.0, 4.0]);
        let sum = a.add(&a);
        assert_eq!(sum.data, vec![2.0, -4.0, 6.0, 8.0]);
        let r = a.reshape_items(&[1, 1, 2]);
        assert_eq!(r.shape, vec![1, 1, 2]);
        assert_eq!(r.item_len(), 2);
    }

    #[test]
    #[should_panic(expected = "share one shape")]
    fn mixed_shape_batch_panics() {
        let _ = BatchTensor::from_items(&[
            Tensor::zeros(&[2]),
            Tensor::zeros(&[3]),
        ]);
    }
}

//! Minimal inference-grade neural-network substrate.
//!
//! The paper evaluates CNN-3 (FashionMNIST), VGG-8 (CIFAR-10) and
//! ResNet-18 (CIFAR-100). Training happens at build time in JAX
//! (`python/compile/dst.py`); this module executes the *deployed* models
//! — conv lowered through im2col into chunked matmuls — against a
//! pluggable [`MatmulEngine`], which is either the exact CPU reference or
//! the photonic digital twin (`coordinator::PhotonicEngine`).

pub mod fit;
pub mod im2col;
pub mod layers;
pub mod loader;
pub mod models;
pub mod tensor;

pub use fit::fit_prototype_readout;
pub use im2col::{im2col, im2col_batch};
pub use layers::{Layer, Model};
pub use models::{cnn3, mlp, resnet18, vgg8};
pub use tensor::{BatchTensor, Tensor};

/// A matrix-multiplication backend: computes `Y = W · X` where W is
/// `out_dim × in_dim` (row-major) and X is `in_dim × n_cols` (row-major).
///
/// `layer` names the layer for energy accounting; photonic engines apply
/// that layer's sparsity mask and non-idealities.
pub trait MatmulEngine {
    fn matmul(
        &mut self,
        layer: &str,
        w: &[f64],
        x: &[f64],
        out_dim: usize,
        in_dim: usize,
        n_cols: usize,
    ) -> Vec<f64>;

    /// Batched matmul: `x` packs `batch` independent activation panels of
    /// `cols_per_item` columns each, **item-major** (total `n_cols =
    /// batch · cols_per_item`; item `b`'s columns at `[b·cols_per_item,
    /// (b+1)·cols_per_item)`), and the result uses the same column
    /// layout.
    ///
    /// **Column-offset convention**: stochastic engines must treat each
    /// item's column range as the column range of a *separate* per-item
    /// call — i.e. draw per-column randomness keyed on `(item, col %
    /// cols_per_item)`, not on the packed column index — so a batched
    /// call is value-identical to the `batch` sequential [`Self::matmul`]
    /// calls it replaces (see `PhotonicEngine`'s counter-based noise
    /// streams). The default forwards to one plain [`Self::matmul`] over
    /// the packed panel, which is already item-equivalent for
    /// deterministic column-independent engines ([`ExactEngine`]).
    fn matmul_batch(
        &mut self,
        layer: &str,
        w: &[f64],
        x: &[f64],
        out_dim: usize,
        in_dim: usize,
        cols_per_item: usize,
        batch: usize,
    ) -> Vec<f64> {
        self.matmul(layer, w, x, out_dim, in_dim, cols_per_item * batch)
    }

    /// Open a batched-forward context: the next `matmuls_per_item`
    /// [`Self::matmul_batch`] calls together carry one whole batch of
    /// `batch` items through the model. Stochastic engines use this to
    /// line their per-item randomness up with the sequential schedule
    /// (`Model::forward_batch` passes the model's matmul-layer count);
    /// deterministic engines ignore it (default no-op).
    fn begin_batch(&mut self, _batch: usize, _matmuls_per_item: u64) {}

    /// Close the context opened by [`Self::begin_batch`] (default no-op).
    fn end_batch(&mut self) {}
}

/// Exact f64 reference engine.
#[derive(Debug, Default, Clone)]
pub struct ExactEngine;

impl MatmulEngine for ExactEngine {
    fn matmul(
        &mut self,
        _layer: &str,
        w: &[f64],
        x: &[f64],
        out_dim: usize,
        in_dim: usize,
        n_cols: usize,
    ) -> Vec<f64> {
        assert_eq!(w.len(), out_dim * in_dim);
        assert_eq!(x.len(), in_dim * n_cols);
        let mut y = vec![0.0; out_dim * n_cols];
        for o in 0..out_dim {
            let wrow = &w[o * in_dim..(o + 1) * in_dim];
            for (i, &wv) in wrow.iter().enumerate() {
                if wv == 0.0 {
                    continue;
                }
                let xrow = &x[i * n_cols..(i + 1) * n_cols];
                let yrow = &mut y[o * n_cols..(o + 1) * n_cols];
                for (yv, &xv) in yrow.iter_mut().zip(xrow) {
                    *yv += wv * xv;
                }
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_engine_small() {
        let mut e = ExactEngine;
        // W = [[1,2],[3,4]], X = [[1],[1]]
        let y = e.matmul("t", &[1.0, 2.0, 3.0, 4.0], &[1.0, 1.0], 2, 2, 1);
        assert_eq!(y, vec![3.0, 7.0]);
    }

    #[test]
    fn exact_engine_multi_col() {
        let mut e = ExactEngine;
        // W = [[1,0],[0,1]], X = 2x3
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let y = e.matmul("t", &[1.0, 0.0, 0.0, 1.0], &x, 2, 2, 3);
        assert_eq!(y, x);
    }
}

//! Prototype (nearest-centroid) readout fitting.
//!
//! The paper's accuracy tables need *trained* models. The full DST
//! training runs in JAX at build time (`python/compile/dst.py`) and its
//! weights load via `nn::loader`; for self-contained rust runs (tests,
//! benches without artifacts) we fit only the final linear layer as a
//! prototype classifier on the frozen (random-feature) backbone:
//! `w_k = 2·μ_k`, `b_k = −‖μ_k‖²`, which ranks classes by distance to the
//! class centroid μ_k in feature space — a classical, closed-form, and
//! deterministic training rule that reaches high accuracy on the
//! class-template synthetic datasets.

use crate::data::SyntheticDataset;
use crate::nn::{ExactEngine, Layer, Model, Tensor};

/// Features of `x` just before the final linear layer.
fn backbone_features(model: &Model, x: Tensor) -> Tensor {
    let mut cur = x;
    for l in &model.layers[..model.layers.len() - 1] {
        cur = l.forward(cur, &mut ExactEngine);
    }
    cur
}

/// Fit the last layer (must be `Linear`) as a prototype classifier from
/// `n_train` samples. Returns training accuracy measured on those samples.
pub fn fit_prototype_readout(model: &mut Model, ds: &SyntheticDataset, n_train: usize) -> f64 {
    let (out_dim, in_dim) = match model.layers.last() {
        Some(Layer::Linear { out_dim, in_dim, .. }) => (*out_dim, *in_dim),
        _ => panic!("fit_prototype_readout requires a trailing Linear layer"),
    };
    assert_eq!(out_dim, ds.spec.n_classes, "readout width must match classes");

    // class centroids in feature space
    let mut centroids = vec![vec![0.0f64; in_dim]; out_dim];
    let mut counts = vec![0usize; out_dim];
    let mut feats = Vec::with_capacity(n_train);
    for i in 0..n_train {
        let (img, label) = ds.sample(0xF17, i);
        let f = backbone_features(model, img);
        assert_eq!(f.numel(), in_dim, "backbone feature dim");
        for (c, &v) in centroids[label].iter_mut().zip(&f.data) {
            *c += v;
        }
        counts[label] += 1;
        feats.push((f, label));
    }
    for (c, &n) in centroids.iter_mut().zip(&counts) {
        if n > 0 {
            for v in c.iter_mut() {
                *v /= n as f64;
            }
        }
    }

    // w_k = 2 μ_k, b_k = −‖μ_k‖²  (argmax == nearest centroid)
    if let Some(Layer::Linear { weight, bias, .. }) = model.layers.last_mut() {
        for k in 0..out_dim {
            let norm2: f64 = centroids[k].iter().map(|v| v * v).sum();
            for j in 0..in_dim {
                weight[k * in_dim + j] = 2.0 * centroids[k][j];
            }
            bias[k] = -norm2;
        }
    }

    // training accuracy
    let mut correct = 0usize;
    for (f, label) in &feats {
        let mut best = (f64::NEG_INFINITY, 0usize);
        for k in 0..out_dim {
            let norm2: f64 = centroids[k].iter().map(|v| v * v).sum();
            let dot: f64 = centroids[k].iter().zip(&f.data).map(|(a, b)| a * b).sum();
            let score = 2.0 * dot - norm2;
            if score > best.0 {
                best = (score, k);
            }
        }
        if best.1 == *label {
            correct += 1;
        }
    }
    correct as f64 / n_train.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{evaluate_accuracy, DatasetSpec};

    #[test]
    fn cnn3_prototype_readout_learns_synthetic_fmnist() {
        let ds = SyntheticDataset::new(DatasetSpec::fmnist_like());
        let mut model = crate::nn::models::cnn3();
        let train_acc = fit_prototype_readout(&mut model, &ds, 200);
        assert!(train_acc > 0.8, "train acc {train_acc}");
        // held-out split
        let acc = evaluate_accuracy(&model, &mut ExactEngine, &ds, 0xEEE, 100);
        assert!(acc > 0.75, "test acc {acc}");
    }

    #[test]
    #[should_panic]
    fn requires_linear_tail() {
        let ds = SyntheticDataset::new(DatasetSpec::fmnist_like());
        let mut m = crate::nn::models::cnn3();
        m.layers.push(Layer::Relu);
        let _ = fit_prototype_readout(&mut m, &ds, 10);
    }
}

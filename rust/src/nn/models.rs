//! The paper's model zoo (§4.1):
//!
//! * CNN-3 "C64K3-C64K3-Pool5-FC10" on 1×28×28 (FashionMNIST-shaped);
//! * VGG-8 on 3×32×32 (CIFAR-10-shaped, 10 classes);
//! * ResNet-18 on 3×32×32 (CIFAR-100-shaped, 100 classes).
//!
//! Weights are deterministic Kaiming-style random at construction and are
//! replaced by trained parameters via `loader::load_weights` when a
//! python-trained bundle is available.

use super::layers::{Layer, Model};
use crate::util::XorShiftRng;

fn kaiming(rng: &mut XorShiftRng, fan_in: usize, n: usize) -> Vec<f64> {
    let std = (2.0 / fan_in as f64).sqrt();
    (0..n).map(|_| rng.gaussian_std(std)).collect()
}

fn conv(
    rng: &mut XorShiftRng,
    name: &str,
    in_c: usize,
    out_c: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Layer {
    let fan_in = in_c * k * k;
    Layer::Conv2d {
        name: name.into(),
        out_c,
        in_c,
        k,
        stride,
        pad,
        weight: kaiming(rng, fan_in, out_c * fan_in),
        bias: vec![0.0; out_c],
    }
}

fn linear(rng: &mut XorShiftRng, name: &str, in_dim: usize, out_dim: usize) -> Layer {
    Layer::Linear {
        name: name.into(),
        out_dim,
        in_dim,
        weight: kaiming(rng, in_dim, out_dim * in_dim),
        bias: vec![0.0; out_dim],
    }
}

/// CNN-3: C64K3 — C64K3 — Pool5 — FC10 on 1×28×28.
pub fn cnn3() -> Model {
    let mut rng = XorShiftRng::new(0xC3);
    // stride-2 convs keep the FC small while preserving the paper's shape
    let layers = vec![
        conv(&mut rng, "conv1", 1, 64, 3, 1, 1),
        Layer::Relu,
        conv(&mut rng, "conv2", 64, 64, 3, 1, 1),
        Layer::Relu,
        Layer::AvgPool { k: 5 }, // 28 -> 5 (floor), paper's Pool5
        Layer::Flatten,
        linear(&mut rng, "fc", 64 * 5 * 5, 10),
    ];
    Model { name: "cnn3-fmnist".into(), input_shape: vec![1, 28, 28], layers }
}

/// VGG-8: 6 conv + 2 FC on 3×32×32, 10 classes.
pub fn vgg8() -> Model {
    let mut rng = XorShiftRng::new(0x1108);
    let layers = vec![
        conv(&mut rng, "conv1", 3, 64, 3, 1, 1),
        Layer::Relu,
        conv(&mut rng, "conv2", 64, 64, 3, 1, 1),
        Layer::Relu,
        Layer::MaxPool { k: 2 }, // 16
        conv(&mut rng, "conv3", 64, 128, 3, 1, 1),
        Layer::Relu,
        conv(&mut rng, "conv4", 128, 128, 3, 1, 1),
        Layer::Relu,
        Layer::MaxPool { k: 2 }, // 8
        conv(&mut rng, "conv5", 128, 256, 3, 1, 1),
        Layer::Relu,
        conv(&mut rng, "conv6", 256, 256, 3, 1, 1),
        Layer::Relu,
        Layer::MaxPool { k: 2 }, // 4
        Layer::AvgPool { k: 4 }, // global -> 1x1
        Layer::Flatten,
        linear(&mut rng, "fc1", 256, 128),
        Layer::Relu,
        linear(&mut rng, "fc2", 128, 10),
    ];
    Model { name: "vgg8-cifar10".into(), input_shape: vec![3, 32, 32], layers }
}

fn basic_block(
    rng: &mut XorShiftRng,
    name: &str,
    in_c: usize,
    out_c: usize,
    stride: usize,
) -> Layer {
    let body = vec![
        conv(rng, &format!("{name}.conv1"), in_c, out_c, 3, stride, 1),
        Layer::Relu,
        conv(rng, &format!("{name}.conv2"), out_c, out_c, 3, 1, 1),
    ];
    let shortcut = if stride != 1 || in_c != out_c {
        vec![conv(rng, &format!("{name}.down"), in_c, out_c, 1, stride, 0)]
    } else {
        vec![]
    };
    Layer::Residual { body, shortcut }
}

/// ResNet-18 (CIFAR variant): conv3x3-64 stem, 4 stages × 2 BasicBlocks
/// (64/128/256/512), global average pool, FC-100.
pub fn resnet18() -> Model {
    let mut rng = XorShiftRng::new(0x2E18);
    let mut layers = vec![conv(&mut rng, "stem", 3, 64, 3, 1, 1), Layer::Relu];
    let stages = [(64usize, 64usize, 1usize), (64, 128, 2), (128, 256, 2), (256, 512, 2)];
    for (si, &(in_c, out_c, stride)) in stages.iter().enumerate() {
        layers.push(basic_block(&mut rng, &format!("s{si}b0"), in_c, out_c, stride));
        layers.push(basic_block(&mut rng, &format!("s{si}b1"), out_c, out_c, 1));
    }
    layers.push(Layer::AvgPool { k: 4 }); // 32/2/2/2 = 4 -> 1x1
    layers.push(Layer::Flatten);
    layers.push(linear(&mut rng, "fc", 512, 100));
    Model { name: "resnet18-cifar100".into(), input_shape: vec![3, 32, 32], layers }
}

/// MLP-3: Flatten — FC256 — ReLU — FC128 — ReLU — FC10 on 1×28×28.
///
/// The batched-serving stress workload: every matmul layer carries
/// exactly ONE activation column per image, so per-image throughput
/// lives or dies on dynamic batching turning matvec dispatches into one
/// `n_cols = B` matmul (the §3.2 cycle-amortization argument, and
/// ENLighten's transformer-FC serving case). `scatter bench serve`
/// sweeps `--max-batch` over this model for the `b8/b1` CI floor.
pub fn mlp() -> Model {
    let mut rng = XorShiftRng::new(0x317);
    let layers = vec![
        Layer::Flatten,
        linear(&mut rng, "fc1", 28 * 28, 256),
        Layer::Relu,
        linear(&mut rng, "fc2", 256, 128),
        Layer::Relu,
        linear(&mut rng, "fc3", 128, 10),
    ];
    Model { name: "mlp3-fmnist".into(), input_shape: vec![1, 28, 28], layers }
}

/// Look a model up by name.
pub fn by_name(name: &str) -> Option<Model> {
    match name {
        "cnn3" | "cnn3-fmnist" => Some(cnn3()),
        "vgg8" | "vgg8-cifar10" => Some(vgg8()),
        "resnet18" | "resnet18-cifar100" => Some(resnet18()),
        "mlp" | "mlp3" | "mlp3-fmnist" => Some(mlp()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{ExactEngine, Tensor};

    #[test]
    fn cnn3_forward_shape() {
        let m = cnn3();
        let y = m.forward(Tensor::zeros(&[1, 28, 28]), &mut ExactEngine);
        assert_eq!(y.shape, vec![10]);
    }

    #[test]
    fn vgg8_forward_shape() {
        let m = vgg8();
        let y = m.forward(Tensor::zeros(&[3, 32, 32]), &mut ExactEngine);
        assert_eq!(y.shape, vec![10]);
    }

    #[test]
    fn resnet18_forward_shape() {
        let m = resnet18();
        let y = m.forward(Tensor::zeros(&[3, 32, 32]), &mut ExactEngine);
        assert_eq!(y.shape, vec![100]);
    }

    #[test]
    fn resnet18_has_20_matmul_layers() {
        // stem + 16 block convs + 3 downsamples + fc = 21
        let m = resnet18();
        assert_eq!(m.matmul_layers().len(), 21);
    }

    #[test]
    fn deterministic_construction() {
        let a = cnn3();
        let b = cnn3();
        let (wa, wb) = match (&a.layers[0], &b.layers[0]) {
            (Layer::Conv2d { weight: wa, .. }, Layer::Conv2d { weight: wb, .. }) => (wa, wb),
            _ => panic!(),
        };
        assert_eq!(wa, wb);
    }

    #[test]
    fn mlp_forward_shape_and_layers() {
        let m = mlp();
        let y = m.forward(Tensor::zeros(&[1, 28, 28]), &mut ExactEngine);
        assert_eq!(y.shape, vec![10]);
        let names: Vec<String> =
            m.matmul_layers().iter().map(|(n, _, _)| n.clone()).collect();
        assert_eq!(names, vec!["fc1", "fc2", "fc3"]);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("cnn3").is_some());
        assert!(by_name("vgg8").is_some());
        assert!(by_name("resnet18").is_some());
        assert!(by_name("mlp").is_some());
        assert!(by_name("nope").is_none());
    }
}

//! Sparsity-compiled chunk execution plans.
//!
//! The legacy matmul streamed every activation column through every
//! `k1×k2` PTC block with per-element `Option<&[bool]>` mask branching —
//! pruned rows/columns still cost control flow, and the access pattern
//! was column-major strided. A [`ChunkPlan`] compiles all of that away at
//! `program_layer` time (the SIGE gather/scatter recipe, applied to the
//! photonic twin):
//!
//! * **active-index gather tables** — `rows` holds the chunk-local output
//!   rows that are actually computed (output gating + out-dim clipping
//!   folded in), `cols` the chunk-local input columns whose effective
//!   port gain is nonzero (input gating / LR folded in; under
//!   `ColumnMode::PruneOnly` every in-range column stays, because pruned
//!   paths physically leak `δw·x`);
//! * **gain-folded weight panel** — `w[ri][ci] = w_real · u_gain · lr_gain`
//!   over (rows × cols), register-block-packed for the
//!   [`PackedPanel`](crate::exec::kernel::PackedPanel) micro-kernel
//!   (4-row quads × nonzero column runs), so the hot loop is a
//!   branch-free panel GEMM that skips pruned work entirely;
//! * **constant leakage bias** — input-gated columns leak the
//!   extinction-ratio floor of the CW carrier *independently of the
//!   activation* (Eq. 13); that whole term collapses to one per-row
//!   constant `bias[ri] = Σ_j w_real · u_floor · lr_gain` added once per
//!   streamed column.
//!
//! The plan is exactly the realized-physics matmul of the programmed
//! blocks: for every (row, col) pair the planned product
//! `(w_real·u_gain·lr_gain)·x` equals the legacy `(w_real·(x·u_gain))·lr_gain`
//! up to floating-point re-association, and the bias term equals the
//! legacy floor contributions summed over *all* k2 columns (including
//! grid-padding columns, which legacy streams as x = 0 but which still
//! leak their floor).

use crate::exec::kernel::{detected_simd, PackedPanel, QuantPanel, SimdLevel};
use crate::ptc::crossbar::ProgrammedPtc;

/// A compiled execution plan for one `rk1 × ck2` programmed chunk.
#[derive(Debug, Clone, Default)]
pub struct ChunkPlan {
    /// Chunk-local output rows to compute (active under output gating and
    /// within the layer's `out_dim`), ascending.
    pub rows: Vec<u32>,
    /// Chunk-local input columns with nonzero port gain (and within the
    /// layer's `in_dim`), ascending. Gather indices into the activation
    /// panel. Invariant under thermal re-realization
    /// (`ProgrammedPtc::realize_drifted` perturbs `w_real` only), which
    /// is what lets the engine's shared-panel groups survive per-chunk
    /// recalibration without re-derivation.
    pub cols: Vec<u32>,
    /// Gain-folded realized weights, row-major `rows.len() × cols.len()`
    /// — the dense panel [`Self::accumulate_scalar`] (the pre-PR4
    /// baseline path) sweeps. Deliberately kept alongside the packed
    /// copy: ~`rows·cols·8 B` per chunk, small next to the programmed
    /// blocks' realized state, and it keeps the bench baseline and the
    /// equivalence oracle runnable on any engine.
    pub w: Vec<f64>,
    /// The same weights packed for the register-blocked micro-kernel
    /// (4-row quads × nonzero column runs; see [`PackedPanel`]).
    pub panel: PackedPanel,
    /// The same weights re-quantized to `i16` codes and packed into
    /// lane-width row panels for the integer SIMD kernel
    /// ([`QuantPanel`]); swept by [`Self::accumulate_quant`] when the
    /// engine runs `KernelPrecision::Quantized`.
    pub qpanel: QuantPanel,
    /// Per-exec-row constant leakage term (already LR-rescaled).
    pub bias: Vec<f64>,
    /// True if any bias entry is nonzero (skip the add otherwise).
    any_bias: bool,
    /// Per-row PD-noise std for this chunk (0 when noise is off).
    pub noise_std: f64,
    /// Mask generation this plan was compiled from (0 = baseline
    /// deployment masks). Stamped by the engine at `program_layer` /
    /// incremental-reprogram time and preserved across thermal rebakes,
    /// so a hot-swapped chunk is attributable to the artifact that
    /// produced it.
    pub mask_gen: u64,
}

impl ChunkPlan {
    /// Compile the plan from a chunk's r·c programmed PTC blocks
    /// (row-major over the (a, b) grid, as built by `program_layer`).
    ///
    /// `row_limit`/`col_limit` clip the chunk to the layer's real
    /// `out_dim`/`in_dim` (grid-padding rows are never computed; padding
    /// columns carry no signal but their gating floor still leaks into
    /// `bias`).
    pub fn from_blocks(
        blocks: &[ProgrammedPtc],
        r: usize,
        c: usize,
        row_limit: usize,
        col_limit: usize,
        noise_std: f64,
    ) -> Self {
        assert_eq!(blocks.len(), r * c, "chunk must hold r*c programmed blocks");
        if blocks.is_empty() {
            // degenerate layer (out_dim or in_dim of 0 schedules no
            // blocks): an empty plan, not a blocks[0] panic
            return Self { noise_std, ..Self::default() };
        }
        let (k1, k2) = (blocks[0].k1, blocks[0].k2);
        assert!(row_limit <= r * k1 && col_limit <= c * k2);

        // active-index gather tables
        let mut rows = Vec::new();
        for row in 0..row_limit {
            let (a, i) = (row / k1, row % k1);
            let blk = &blocks[a * c];
            if !blk.output_gating || blk.row_mask[i] {
                rows.push(row as u32);
            }
        }
        let mut cols = Vec::new();
        for col in 0..col_limit {
            let (b, j) = (col / k2, col % k2);
            if blocks[b].u_gain[j] != 0.0 {
                cols.push(col as u32);
            }
        }

        // gain-folded dense panel over (active rows × active cols)
        let mut w = vec![0.0f64; rows.len() * cols.len()];
        for (ri, &row) in rows.iter().enumerate() {
            let (a, i) = (row as usize / k1, row as usize % k1);
            for (ci, &col) in cols.iter().enumerate() {
                let (b, j) = (col as usize / k2, col as usize % k2);
                let blk = &blocks[a * c + b];
                w[ri * cols.len() + ci] =
                    blk.w_real[i * k2 + j] * blk.u_gain[j] * blk.lr_gain;
            }
        }

        // constant leakage bias: floor contributions over ALL k2 columns
        // of every b-block (padding columns included — legacy streams
        // them as x = 0 but their gated modulators still leak)
        let mut bias = vec![0.0f64; rows.len()];
        let mut any_bias = false;
        for (ri, &row) in rows.iter().enumerate() {
            let (a, i) = (row as usize / k1, row as usize % k1);
            let mut acc = 0.0;
            for b in 0..c {
                let blk = &blocks[a * c + b];
                let mut block_acc = 0.0;
                for j in 0..k2 {
                    if blk.u_floor[j] != 0.0 {
                        block_acc += blk.w_real[i * k2 + j] * blk.u_floor[j];
                    }
                }
                acc += block_acc * blk.lr_gain;
            }
            bias[ri] = acc;
            any_bias |= acc != 0.0;
        }

        let panel = PackedPanel::pack(&w, rows.len(), cols.len());
        let qpanel =
            QuantPanel::pack(&w, rows.len(), cols.len(), detected_simd().lanes());
        Self { rows, cols, w, panel, qpanel, bias, any_bias, noise_std, mask_gen: 0 }
    }

    /// Active input columns (the gather count per streamed column block).
    pub fn n_active_cols(&self) -> usize {
        self.cols.len()
    }

    /// Fixed-seed sentinel probe over `n` active columns. Every entry is
    /// bounded away from zero (0.25..1.0) so a dead or stuck device
    /// always moves the response, and the same `n` always yields the
    /// same vector — a golden response captured at program time stays
    /// index-aligned with a live one because device faults mutate only
    /// realized weights, never the gather tables.
    pub fn sentinel_probe(n: usize) -> Vec<f64> {
        let mut rng = crate::util::XorShiftRng::from_stream(0x5E17_11E1, &[n as u64]);
        (0..n).map(|_| rng.uniform_in(0.25, 1.0)).collect()
    }

    /// Noise-free response of this plan to a probe over its active
    /// columns: `bias[ri] + Σ_ci w[ri·nc+ci] · probe[ci]` in ascending
    /// column order, so two plans with bit-identical weights produce
    /// bit-identical responses — the sentinel's comparison primitive.
    pub fn sentinel_response(&self, probe: &[f64]) -> Vec<f64> {
        let nc = self.cols.len();
        assert_eq!(probe.len(), nc, "probe must cover the active columns");
        (0..self.rows.len())
            .map(|ri| {
                let wrow = &self.w[ri * nc..(ri + 1) * nc];
                let mut acc = self.bias[ri];
                for (ci, &wv) in wrow.iter().enumerate() {
                    acc += wv * probe[ci];
                }
                acc
            })
            .collect()
    }

    /// Accumulate this chunk's contribution for a block of `bcols`
    /// activation columns into `buf` (chunk-local rows × `bcols`,
    /// row-major, stride `bcols`).
    ///
    /// `xq` is the gathered + normalized + quantized activation panel:
    /// `cols.len() × bcols`, row-major — i.e. `xq[ci*bcols + t]` is active
    /// column `cols[ci]` of streamed column `t`. The bias adds first (one
    /// constant per active row), then the register-blocked
    /// [`PackedPanel`] micro-kernel sweeps 4-row quads over contiguous
    /// `w`/`xq` runs: zero branches, zero gather indirection, and each
    /// `xq` row loaded once per quad instead of once per row.
    pub fn accumulate(&self, xq: &[f64], bcols: usize, buf: &mut [f64]) {
        debug_assert_eq!(xq.len(), self.cols.len() * bcols);
        if self.any_bias {
            for (ri, &row) in self.rows.iter().enumerate() {
                let dst = &mut buf[row as usize * bcols..row as usize * bcols + bcols];
                let b = self.bias[ri];
                for v in dst.iter_mut() {
                    *v += b;
                }
            }
        }
        self.panel.accumulate(xq, bcols, buf, &self.rows);
    }

    /// The integer-quantized counterpart of [`Self::accumulate`]: same
    /// bias-first contract, but `xq` holds `i16` activation codes on the
    /// [`ACT_LEVELS`](crate::exec::kernel::ACT_LEVELS) grid and the
    /// sweep runs the [`QuantPanel`] integer kernel at the given
    /// [`SimdLevel`]. Scalar and SIMD levels are bit-identical (same
    /// `i32` sums, same single f64 fold per output element).
    pub fn accumulate_quant(
        &self,
        xq: &[i16],
        bcols: usize,
        buf: &mut [f64],
        level: SimdLevel,
    ) {
        debug_assert_eq!(xq.len(), self.cols.len() * bcols);
        if self.any_bias {
            for (ri, &row) in self.rows.iter().enumerate() {
                let dst = &mut buf[row as usize * bcols..row as usize * bcols + bcols];
                let b = self.bias[ri];
                for v in dst.iter_mut() {
                    *v += b;
                }
            }
        }
        self.qpanel.accumulate(xq, bcols, buf, &self.rows, level);
    }

    /// The pre-PR4 scalar sweep: one row at a time over the dense panel
    /// with an `if wv == 0.0 { continue }` branch per weight. Kept as
    /// the faithful PR1 execution for
    /// `PhotonicEngine::matmul_uncached` (bench baseline + equivalence
    /// oracle). Value-identical to [`Self::accumulate`]: both add the
    /// nonzero MAC terms of every output element in ascending
    /// active-column order — the register-blocked kernel merely also
    /// adds exact `0·x` no-ops where a 4-row quad straddles a zero
    /// weight (at worst flipping a zero's sign, invisible to `==`).
    pub fn accumulate_scalar(&self, xq: &[f64], bcols: usize, buf: &mut [f64]) {
        let nc = self.cols.len();
        debug_assert_eq!(xq.len(), nc * bcols);
        for (ri, &row) in self.rows.iter().enumerate() {
            let dst = &mut buf[row as usize * bcols..row as usize * bcols + bcols];
            if self.any_bias {
                let b = self.bias[ri];
                for v in dst.iter_mut() {
                    *v += b;
                }
            }
            let wrow = &self.w[ri * nc..(ri + 1) * nc];
            for (ci, &wv) in wrow.iter().enumerate() {
                if wv == 0.0 {
                    continue;
                }
                let xrow = &xq[ci * bcols..(ci + 1) * bcols];
                for (d, &xv) in dst.iter_mut().zip(xrow) {
                    *d += wv * xv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::DeviceLibrary;
    use crate::ptc::crossbar::{ColumnMode, ForwardOptions, PtcSimulator};
    use crate::thermal::{coupling::ArrayGeometry, GammaModel};
    use crate::util::XorShiftRng;

    fn sim(k: usize) -> PtcSimulator {
        let geom = ArrayGeometry { rows: k, cols: k, l_v: 120.0, l_h: 16.0, l_s: 9.0 };
        PtcSimulator::new(geom, &GammaModel::paper(), DeviceLibrary::default())
    }

    /// Program an r×c grid of blocks for one chunk, mirroring
    /// `PhotonicEngine::program_layer`.
    fn program_chunk(
        s: &PtcSimulator,
        r: usize,
        c: usize,
        w: &[f64],
        row_mask: &[bool],
        col_mask: &[bool],
        mode: ColumnMode,
        og: bool,
        seed: u64,
    ) -> Vec<ProgrammedPtc> {
        let (k1, k2) = (s.k1, s.k2);
        let cols = c * k2;
        let mut rng = XorShiftRng::new(seed);
        let mut blocks = Vec::with_capacity(r * c);
        for a in 0..r {
            let rm = &row_mask[a * k1..(a + 1) * k1];
            for b in 0..c {
                let cm = &col_mask[b * k2..(b + 1) * k2];
                let mut wb = vec![0.0f64; k1 * k2];
                for i in 0..k1 {
                    let src = (a * k1 + i) * cols + b * k2;
                    wb[i * k2..(i + 1) * k2].copy_from_slice(&w[src..src + k2]);
                }
                let fo = ForwardOptions {
                    thermal: true,
                    pd_noise: false,
                    phase_noise: false,
                    col_mask: Some(cm),
                    row_mask: Some(rm),
                    col_mode: mode,
                    output_gating: og,
                };
                blocks.push(s.program(&wb, &fo, &mut rng));
            }
        }
        blocks
    }

    /// The plan's single-column output must equal streaming the same
    /// input through the programmed blocks one at a time.
    #[test]
    fn plan_matches_programmed_blocks_all_modes() {
        let (r, c) = (2, 2);
        let s = sim(8);
        let (rows, cols) = (r * s.k1, c * s.k2);
        let mut rng = XorShiftRng::new(11);
        let mut w = vec![0.0; rows * cols];
        rng.fill_uniform(&mut w, -1.0, 1.0);
        let mut x = vec![0.0; cols];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        let row_mask: Vec<bool> = (0..rows).map(|i| i % 3 != 1).collect();
        let col_mask: Vec<bool> = (0..cols).map(|j| j % 2 == 0).collect();

        for (mode, og) in [
            (ColumnMode::PruneOnly, false),
            (ColumnMode::InputGating, false),
            (ColumnMode::InputGating, true),
            (ColumnMode::InputGatingLr, true),
        ] {
            let mut blocks =
                program_chunk(&s, r, c, &w, &row_mask, &col_mask, mode, og, 5);
            // legacy: stream through each block, accumulate per tile row
            let mut y_legacy = vec![0.0f64; rows];
            let mut nrng = XorShiftRng::new(0);
            for a in 0..r {
                for b in 0..c {
                    let mut yb = vec![0.0f64; s.k1];
                    blocks[a * c + b].run_into(
                        &x[b * s.k2..(b + 1) * s.k2],
                        &mut yb,
                        &mut nrng,
                    );
                    for i in 0..s.k1 {
                        y_legacy[a * s.k1 + i] += yb[i];
                    }
                }
            }

            // planned: gather active cols, one accumulate call
            let plan = ChunkPlan::from_blocks(&blocks, r, c, rows, cols, 0.0);
            let xq: Vec<f64> =
                plan.cols.iter().map(|&j| x[j as usize].max(0.0)).collect();
            let mut buf = vec![0.0f64; rows];
            plan.accumulate(&xq, 1, &mut buf);

            for i in 0..rows {
                assert!(
                    (buf[i] - y_legacy[i]).abs() < 1e-9,
                    "mode {mode:?} og {og} row {i}: plan {} vs legacy {}",
                    buf[i],
                    y_legacy[i]
                );
            }
            // gated rows must be exact zeros in both paths
            if og {
                for i in 0..rows {
                    if !row_mask[i] {
                        assert_eq!(buf[i], 0.0);
                        assert_eq!(y_legacy[i], 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn plan_skips_pruned_work_under_gating_but_not_prune_only() {
        let (r, c) = (1, 2);
        let s = sim(8);
        let (rows, cols) = (r * s.k1, c * s.k2);
        let w = vec![0.5; rows * cols];
        let row_mask = vec![true; rows];
        let col_mask: Vec<bool> = (0..cols).map(|j| j % 4 == 0).collect(); // 25% active

        let gated = program_chunk(
            &s, r, c, &w, &row_mask, &col_mask, ColumnMode::InputGatingLr, true, 1,
        );
        let plan = ChunkPlan::from_blocks(&gated, r, c, rows, cols, 0.0);
        assert_eq!(plan.n_active_cols(), cols / 4, "LR plan gathers only active cols");

        let prune = program_chunk(
            &s, r, c, &w, &row_mask, &col_mask, ColumnMode::PruneOnly, false, 1,
        );
        let plan = ChunkPlan::from_blocks(&prune, r, c, rows, cols, 0.0);
        assert_eq!(plan.n_active_cols(), cols, "prune-only leaks through every port");
    }

    #[test]
    fn plan_clips_padding_rows_and_cols() {
        let (r, c) = (1, 1);
        let s = sim(8);
        let w = vec![0.25; 64];
        let mask = vec![true; 8];
        let blocks =
            program_chunk(&s, r, c, &w, &mask, &mask, ColumnMode::PruneOnly, false, 2);
        let plan = ChunkPlan::from_blocks(&blocks, r, c, 5, 6, 0.0);
        assert_eq!(plan.rows, vec![0, 1, 2, 3, 4]);
        assert_eq!(plan.cols, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(plan.w.len(), 30);
        assert_eq!(plan.panel.dims(), (5, 6));
    }

    /// The register-blocked kernel path and the pre-PR4 scalar sweep
    /// must agree on every plan (they share per-element term order).
    #[test]
    fn packed_and_scalar_accumulate_agree() {
        let (r, c) = (2, 2);
        let s = sim(8);
        let (rows, cols) = (r * s.k1, c * s.k2);
        let mut rng = XorShiftRng::new(19);
        let mut w = vec![0.0; rows * cols];
        rng.fill_uniform(&mut w, -1.0, 1.0);
        let row_mask: Vec<bool> = (0..rows).map(|i| i % 4 != 2).collect();
        let col_mask: Vec<bool> = (0..cols).map(|j| j % 3 != 1).collect();
        let blocks = program_chunk(
            &s, r, c, &w, &row_mask, &col_mask, ColumnMode::InputGatingLr, true, 6,
        );
        let plan = ChunkPlan::from_blocks(&blocks, r, c, rows - 3, cols - 5, 0.0);
        for bcols in [1usize, 3, 7] {
            let mut xq = vec![0.0; plan.n_active_cols() * bcols];
            rng.fill_uniform(&mut xq, 0.0, 1.0);
            let mut a = vec![0.0f64; rows * bcols];
            let mut b = vec![0.0f64; rows * bcols];
            plan.accumulate(&xq, bcols, &mut a);
            plan.accumulate_scalar(&xq, bcols, &mut b);
            assert_eq!(a, b, "bcols {bcols}");
        }
    }

    /// The quantized plan sweep must track the f64 kernel within weight
    /// quantization error (bias included), and every SIMD level must be
    /// bit-identical to the scalar integer level.
    #[test]
    fn quant_accumulate_tracks_packed_and_is_level_invariant() {
        use crate::exec::kernel::ACT_LEVELS;
        let (r, c) = (2, 2);
        let s = sim(8);
        let (rows, cols) = (r * s.k1, c * s.k2);
        let mut rng = XorShiftRng::new(29);
        let mut w = vec![0.0; rows * cols];
        rng.fill_uniform(&mut w, -1.0, 1.0);
        let row_mask: Vec<bool> = (0..rows).map(|i| i % 4 != 2).collect();
        let col_mask: Vec<bool> = (0..cols).map(|j| j % 3 != 1).collect();
        let blocks = program_chunk(
            &s, r, c, &w, &row_mask, &col_mask, ColumnMode::InputGatingLr, true, 6,
        );
        let plan = ChunkPlan::from_blocks(&blocks, r, c, rows - 3, cols - 5, 0.0);
        let nc = plan.n_active_cols();
        for bcols in [1usize, 3, 8, 17] {
            let codes: Vec<i16> = (0..nc * bcols)
                .map(|_| (rng.uniform() * ACT_LEVELS).round() as i16)
                .collect();
            let xf: Vec<f64> = codes.iter().map(|&v| v as f64 / ACT_LEVELS).collect();
            let mut exact = vec![0.0f64; rows * bcols];
            plan.accumulate(&xf, bcols, &mut exact);
            let mut scalar = vec![0.0f64; rows * bcols];
            plan.accumulate_quant(&codes, bcols, &mut scalar, SimdLevel::Scalar);
            let tol = nc as f64 / 254.0 * 1.05 + 1e-9;
            for (i, (q, e)) in scalar.iter().zip(&exact).enumerate() {
                assert!(
                    (q - e).abs() <= tol,
                    "bcols {bcols} idx {i}: quant {q} vs exact {e} (tol {tol})"
                );
            }
            let mut simd = vec![0.0f64; rows * bcols];
            plan.accumulate_quant(&codes, bcols, &mut simd, detected_simd());
            assert_eq!(simd, scalar, "bcols {bcols}: level must not change bits");
        }
    }

    /// The sentinel primitive: a deterministic, strictly-positive probe
    /// whose plan response equals the scalar sweep's single-column
    /// output on every active row.
    #[test]
    fn sentinel_response_matches_single_column_sweep() {
        let (r, c) = (2, 2);
        let s = sim(8);
        let (rows, cols) = (r * s.k1, c * s.k2);
        let mut rng = XorShiftRng::new(23);
        let mut w = vec![0.0; rows * cols];
        rng.fill_uniform(&mut w, -1.0, 1.0);
        let row_mask: Vec<bool> = (0..rows).map(|i| i % 5 != 3).collect();
        let col_mask: Vec<bool> = (0..cols).map(|j| j % 2 == 0).collect();
        let blocks = program_chunk(
            &s, r, c, &w, &row_mask, &col_mask, ColumnMode::InputGatingLr, true, 7,
        );
        let plan = ChunkPlan::from_blocks(&blocks, r, c, rows, cols, 0.0);

        let probe = ChunkPlan::sentinel_probe(plan.n_active_cols());
        assert!(probe.iter().all(|&v| (0.25..1.0).contains(&v)), "bounded away from zero");
        assert_eq!(
            probe,
            ChunkPlan::sentinel_probe(plan.n_active_cols()),
            "probe is a pure function of the column count"
        );

        let resp = plan.sentinel_response(&probe);
        assert_eq!(resp.len(), plan.rows.len());
        let mut buf = vec![0.0f64; rows];
        plan.accumulate_scalar(&probe, 1, &mut buf);
        for (ri, &row) in plan.rows.iter().enumerate() {
            assert_eq!(resp[ri], buf[row as usize], "active row {row}");
        }
    }

    /// Degenerate layers schedule zero blocks; the plan must come back
    /// empty instead of indexing `blocks[0]` (regression: PR 4).
    #[test]
    fn from_blocks_of_empty_chunk_is_empty_plan() {
        let plan = ChunkPlan::from_blocks(&[], 0, 0, 0, 0, 0.125);
        assert!(plan.rows.is_empty() && plan.cols.is_empty());
        assert_eq!(plan.panel.dims(), (0, 0));
        assert_eq!(plan.noise_std, 0.125);
        let mut buf: Vec<f64> = Vec::new();
        plan.accumulate(&[], 1, &mut buf); // no-op, no panic
    }
}

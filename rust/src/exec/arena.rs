//! Allocation-free steady-state support for the compiled execution
//! path: per-worker scratch arenas, the shared activation-panel cache,
//! and the per-stage wall-time instrumentation behind
//! `scatter bench engine --stages`.
//!
//! The PR1 execution loop allocated a fresh `vec![0.0; rows*bcols]`
//! accumulator (plus an `xq` gather buffer) per work item and collected
//! every item's buffer into a `Vec<Vec<f64>>` before scattering. With
//! the panel cache ([`PanelCache`]) the gather buffers become shared
//! read-only slabs materialized once per (gather-table, column-block),
//! and with [`WorkerArena`] each pool worker reuses one accumulator slab
//! across all the items it claims — the steady-state hot path performs
//! no heap allocation beyond the returned output vector.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Per-worker scratch, created once per [`parallel_for_with`] worker and
/// reused across every item that worker claims.
///
/// The activation panels are *not* in here: those are shared read-only
/// across workers via [`PanelCache`], which is what removes the O(p×)
/// re-gather redundancy.
///
/// [`parallel_for_with`]: crate::exec::parallel_for_with
#[derive(Default)]
pub struct WorkerArena {
    buf: Vec<f64>,
}

impl WorkerArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// A zeroed accumulator slab of exactly `len`, reusing the worker's
    /// allocation (grow-only: the slab keeps the largest size seen).
    pub fn zeroed(&mut self, len: usize) -> &mut [f64] {
        if self.buf.len() < len {
            self.buf.resize(len, 0.0);
        }
        let slab = &mut self.buf[..len];
        slab.fill(0.0);
        slab
    }
}

/// The shared quantized-activation panel cache: one flat slab holding,
/// per (distinct gather table, call), a `cols.len() × n_cols` panel in
/// column-blocked layout, plus the per-group offsets into it.
///
/// Layout: group `g`'s panel occupies
/// `slab[offsets[g] .. offsets[g] + cols_len(g) · n_cols]`; within it,
/// the column block starting at `col0` with `bcols` columns is the
/// contiguous sub-slice at `offsets[g] + cols_len(g) · col0`, packed
/// `ci · bcols + t` — exactly the `xq` layout
/// [`ChunkPlan::accumulate`](crate::exec::ChunkPlan::accumulate)
/// consumes, so pass 2 reads panels with zero copies.
///
/// The slab is owned by the engine and reused across matmul calls
/// (grow-only); `prepare` never zeroes it because pass 1 overwrites
/// every region pass 2 reads.
///
/// Under [`KernelPrecision::Quantized`](crate::exec::KernelPrecision)
/// pass 1 instead materializes each panel as `i16` activation codes in a
/// separate 64-byte-aligned slab (same offsets, same layout) sized by
/// [`Self::prepare_quant`] — cache-line alignment keeps the SIMD
/// kernel's streamed loads from straddling lines at panel starts.
#[derive(Default)]
pub struct PanelCache {
    slab: Vec<f64>,
    /// i16 code slab, stored as 64-byte-aligned 32-element lanes so the
    /// slab base is cache-line aligned (`Vec` alignment follows the
    /// element type).
    qslab: Vec<AlignedLane>,
    offsets: Vec<usize>,
    /// Logical element count of the last `prepare` layout (both slabs
    /// share it).
    total: usize,
}

/// One cache line of `i16` activation codes (32 × 2 bytes).
#[repr(C, align(64))]
#[derive(Clone, Copy)]
struct AlignedLane([i16; 32]);

impl PanelCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size the slab for one call: `group_sizes` yields each group's
    /// total panel length (`cols.len() · n_cols`). With the batched
    /// forward path `n_cols` is `batch × cols_per_item`, so the slab
    /// grows to the largest batch seen and then serves every smaller
    /// call allocation-free (grow-only, like [`WorkerArena`]). Returns
    /// nothing; read back via [`Self::offset`] / [`Self::parts_mut`].
    pub fn prepare(&mut self, group_sizes: impl Iterator<Item = usize>) {
        self.offsets.clear();
        let mut total = 0usize;
        for len in group_sizes {
            self.offsets.push(total);
            total += len;
        }
        self.total = total;
        if self.slab.len() < total {
            self.slab.resize(total, 0.0);
        }
    }

    /// Size the `i16` code slab for the layout of the last [`Self::prepare`]
    /// call (grow-only, never zeroed — pass 1 overwrites every region
    /// pass 2 reads). Call after `prepare` when the engine runs the
    /// quantized kernel.
    pub fn prepare_quant(&mut self) {
        let lanes = self.total.div_ceil(32);
        if self.qslab.len() < lanes {
            self.qslab.resize(lanes, AlignedLane([0; 32]));
        }
    }

    /// Slab offset of group `g`'s panel.
    pub fn offset(&self, g: usize) -> usize {
        self.offsets[g]
    }

    /// Per-group offsets + the whole slab, mutable — pass 1 writes
    /// disjoint regions through a
    /// [`DisjointWriter`](crate::exec::DisjointWriter) over the slab
    /// while indexing by offset.
    pub fn parts_mut(&mut self) -> (&[usize], &mut [f64]) {
        (&self.offsets, &mut self.slab)
    }

    /// Per-group offsets + the slab, read-only (pass 2).
    pub fn parts(&self) -> (&[usize], &[f64]) {
        (&self.offsets, &self.slab)
    }

    /// Per-group offsets + the whole `i16` code slab, mutable
    /// (quantized pass 1). Requires a prior [`Self::prepare_quant`].
    pub fn quant_parts_mut(&mut self) -> (&[usize], &mut [i16]) {
        debug_assert!(self.qslab.len() * 32 >= self.total, "prepare_quant first");
        // SAFETY: AlignedLane is repr(C) over [i16; 32], so the Vec's
        // backing memory is `qslab.len() * 32` contiguous, initialized
        // i16s; we expose the logical prefix.
        let q = unsafe {
            std::slice::from_raw_parts_mut(
                self.qslab.as_mut_ptr() as *mut i16,
                self.total,
            )
        };
        (&self.offsets, q)
    }

    /// Per-group offsets + the `i16` code slab, read-only
    /// (quantized pass 2).
    pub fn quant_parts(&self) -> (&[usize], &[i16]) {
        debug_assert!(self.qslab.len() * 32 >= self.total, "prepare_quant first");
        // SAFETY: as in `quant_parts_mut`.
        let q = unsafe {
            std::slice::from_raw_parts(self.qslab.as_ptr() as *const i16, self.total)
        };
        (&self.offsets, q)
    }
}

/// Cumulative per-stage wall time of the execution path, accumulated
/// across pool workers with relaxed atomics. Zero-cost when the engine's
/// stage timing is off (the hot loops skip the `Instant` reads
/// entirely); when on, enables the gather/kernel/scatter breakdown in
/// `BENCH_engine.json` (EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct StageTimes {
    gather_ns: AtomicU64,
    kernel_ns: AtomicU64,
    scatter_ns: AtomicU64,
}

impl StageTimes {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_gather(&self, d: Duration) {
        self.gather_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_kernel(&self, d: Duration) {
        self.kernel_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_scatter(&self, d: Duration) {
        self.scatter_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Drain the counters into a snapshot (resets to zero).
    pub fn take(&self) -> StageBreakdown {
        StageBreakdown {
            gather_ns: self.gather_ns.swap(0, Ordering::Relaxed),
            kernel_ns: self.kernel_ns.swap(0, Ordering::Relaxed),
            scatter_ns: self.scatter_ns.swap(0, Ordering::Relaxed),
        }
    }
}

/// A drained stage-time snapshot. `gather` is activation gather +
/// normalize + quantize, `kernel` is the panel micro-kernel (bias + FMA
/// sweeps), `scatter` is PD-noise injection + the scaled write into the
/// output matrix.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageBreakdown {
    pub gather_ns: u64,
    pub kernel_ns: u64,
    pub scatter_ns: u64,
}

impl StageBreakdown {
    pub fn total_ns(&self) -> u64 {
        self.gather_ns + self.kernel_ns + self.scatter_ns
    }

    /// (gather, kernel, scatter) shares of the summed stage time;
    /// all-zero when nothing was recorded.
    pub fn shares(&self) -> (f64, f64, f64) {
        let total = self.total_ns() as f64;
        if total <= 0.0 {
            return (0.0, 0.0, 0.0);
        }
        (
            self.gather_ns as f64 / total,
            self.kernel_ns as f64 / total,
            self.scatter_ns as f64 / total,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_reuses_and_zeroes() {
        let mut a = WorkerArena::new();
        let s = a.zeroed(8);
        s.fill(3.0);
        let ptr = a.buf.as_ptr();
        let s = a.zeroed(4);
        assert!(s.iter().all(|&v| v == 0.0), "slab must come back zeroed");
        assert_eq!(s.len(), 4);
        assert_eq!(a.buf.as_ptr(), ptr, "shrinking request must not reallocate");
        assert_eq!(a.zeroed(16).len(), 16, "growing request resizes");
    }

    #[test]
    fn panel_cache_offsets_are_prefix_sums() {
        let mut c = PanelCache::new();
        c.prepare([6usize, 0, 10].into_iter());
        assert_eq!(c.offset(0), 0);
        assert_eq!(c.offset(1), 6);
        assert_eq!(c.offset(2), 6);
        assert!(c.parts().1.len() >= 16);
        let grown = c.parts().1.len();
        c.prepare([2usize].into_iter());
        assert_eq!(c.parts().1.len(), grown, "slab is grow-only across calls");
    }

    /// The batched serving path multiplies every group's panel length by
    /// the dynamic batch size; the cache must absorb the growth once and
    /// then serve both batched and unbatched calls without reallocating.
    #[test]
    fn panel_cache_grows_once_for_batched_columns_then_reuses() {
        let mut c = PanelCache::new();
        let (nc_a, nc_b, cols_per_item) = (48usize, 30usize, 25usize);
        c.prepare([nc_a * cols_per_item, nc_b * cols_per_item].into_iter());
        let single = c.parts().1.len();
        assert!(single >= (nc_a + nc_b) * cols_per_item);
        // a batch of 8 images: every panel is 8× wider
        let batch = 8;
        c.prepare(
            [nc_a * cols_per_item * batch, nc_b * cols_per_item * batch].into_iter(),
        );
        assert!(c.parts().1.len() >= (nc_a + nc_b) * cols_per_item * batch);
        assert_eq!(c.offset(1), nc_a * cols_per_item * batch, "offsets track the batch");
        let grown = c.parts().1.len();
        let ptr = c.parts().1.as_ptr();
        // back to batch 1: no shrink, no reallocation
        c.prepare([nc_a * cols_per_item, nc_b * cols_per_item].into_iter());
        assert_eq!(c.parts().1.len(), grown);
        assert_eq!(c.parts().1.as_ptr(), ptr, "smaller batch reuses the slab");
    }

    /// The quantized kernel streams 256-bit loads from the i16 slab;
    /// the slab base must sit on a cache line and track the same
    /// offsets/total as the f64 layout.
    #[test]
    fn quant_slab_is_cache_line_aligned_and_tracks_layout() {
        let mut c = PanelCache::new();
        c.prepare([6usize, 10, 33].into_iter());
        c.prepare_quant();
        {
            let (offsets, q) = c.quant_parts_mut();
            assert_eq!(offsets, &[0, 6, 16]);
            assert_eq!(q.len(), 49);
            assert_eq!(q.as_ptr() as usize % 64, 0, "64-byte aligned slab base");
            for (i, v) in q.iter_mut().enumerate() {
                *v = i as i16;
            }
        }
        let (_, q) = c.quant_parts();
        assert!(q.iter().enumerate().all(|(i, &v)| v == i as i16));
        let cap = c.qslab.len();
        // grow-only across layouts, like the f64 slab
        c.prepare([8usize].into_iter());
        c.prepare_quant();
        assert_eq!(c.quant_parts().1.len(), 8);
        assert_eq!(c.qslab.len(), cap, "smaller layout must not shrink");
    }

    #[test]
    fn stage_times_accumulate_and_drain() {
        let st = StageTimes::new();
        st.add_gather(Duration::from_nanos(10));
        st.add_kernel(Duration::from_nanos(30));
        st.add_scatter(Duration::from_nanos(60));
        let b = st.take();
        assert_eq!(b.total_ns(), 100);
        let (g, k, s) = b.shares();
        assert!((g - 0.1).abs() < 1e-12 && (k - 0.3).abs() < 1e-12 && (s - 0.6).abs() < 1e-12);
        assert_eq!(st.take().total_ns(), 0, "drained");
    }
}

//! Hand-rolled scoped worker pool (the offline toolchain has no rayon).
//!
//! [`parallel_map`] fans a list of independent work items across a fixed
//! number of `std::thread::scope` workers pulling from a shared atomic
//! counter, and returns the results in item order. Because items are
//! claimed dynamically, stragglers load-balance automatically; because
//! results are reassembled by index, the output is independent of which
//! worker computed what.
//!
//! Callers must make the items themselves scheduling-invariant (e.g. the
//! engine's counter-based per-(chunk, column) noise streams) — the pool
//! guarantees only ordering of the result vector, not execution order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Map `f` over `0..n_items` on up to `threads` workers; results are
/// returned in item order. `threads <= 1` (or a single item) runs inline
/// on the caller with zero thread overhead, so a pool of one is exactly
/// the sequential loop.
pub fn parallel_map<T, F>(threads: usize, n_items: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = threads.max(1).min(n_items);
    if workers <= 1 {
        return (0..n_items).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_items {
                    break;
                }
                if tx.send((i, f(i))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
    });

    let mut slots: Vec<Option<T>> = (0..n_items).map(|_| None).collect();
    for (i, v) in rx.iter() {
        slots[i] = Some(v);
    }
    slots
        .into_iter()
        .map(|s| s.expect("worker panicked before finishing its item"))
        .collect()
}

/// Split `n` items into `parts` near-equal contiguous ranges (the last
/// ranges are one shorter when `n % parts != 0`). Empty ranges are
/// omitted, so the result has `min(parts, n)` entries.
pub fn partition_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::new();
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        if len == 0 {
            break;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order_any_thread_count() {
        let expect: Vec<usize> = (0..100).map(|i| i * i).collect();
        for threads in [1, 2, 4, 8, 32] {
            let got = parallel_map(threads, 100, |i| i * i);
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single_item() {
        let got: Vec<usize> = parallel_map(4, 0, |i| i);
        assert!(got.is_empty());
        let got = parallel_map(4, 1, |i| i + 10);
        assert_eq!(got, vec![10]);
    }

    #[test]
    fn workers_actually_run_concurrently() {
        use std::sync::atomic::AtomicUsize;
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static LIVE: AtomicUsize = AtomicUsize::new(0);
        let _ = parallel_map(4, 16, |_| {
            let live = LIVE.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(live, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(5));
            LIVE.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(PEAK.load(Ordering::SeqCst) > 1, "no overlap observed");
    }

    #[test]
    fn partition_covers_everything() {
        for (n, parts) in [(10, 3), (3, 10), (0, 4), (16, 4), (7, 1)] {
            let ranges = partition_ranges(n, parts);
            let total: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(total, n, "n={n} parts={parts}");
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn partition_zero_items_is_empty() {
        assert!(partition_ranges(0, 1).is_empty());
        assert!(partition_ranges(0, 8).is_empty());
    }

    #[test]
    fn partition_more_workers_than_items_yields_singletons() {
        // 3 items over 10 workers: 3 singleton ranges, no empty ranges
        assert_eq!(partition_ranges(3, 10), vec![0..1, 1..2, 2..3]);
        assert_eq!(partition_ranges(1, 4), vec![0..1]);
    }

    #[test]
    fn partition_exact_division_is_uniform() {
        let ranges = partition_ranges(16, 4);
        assert_eq!(ranges, vec![0..4, 4..8, 8..12, 12..16]);
        assert!(ranges.iter().all(|r| r.len() == 4));
    }

    #[test]
    fn partition_remainder_spreads_over_leading_ranges() {
        // 10 = 4 + 3 + 3: the extra item lands on the first range and
        // range sizes never differ by more than one
        let ranges = partition_ranges(10, 3);
        assert_eq!(ranges, vec![0..4, 4..7, 7..10]);
        let (min, max) = (
            ranges.iter().map(|r| r.len()).min().unwrap(),
            ranges.iter().map(|r| r.len()).max().unwrap(),
        );
        assert!(max - min <= 1);
    }

    #[test]
    fn partition_zero_parts_clamps_to_one() {
        assert_eq!(partition_ranges(5, 0), vec![0..5]);
    }
}

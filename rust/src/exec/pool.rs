//! Hand-rolled scoped worker pool (the offline toolchain has no rayon).
//!
//! [`parallel_map`] fans a list of independent work items across a fixed
//! number of `std::thread::scope` workers pulling from a shared atomic
//! counter, and returns the results in item order. Because items are
//! claimed dynamically, stragglers load-balance automatically; because
//! results are reassembled by index, the output is independent of which
//! worker computed what.
//!
//! [`parallel_for_with`] is the allocation-free sibling: no result
//! channel — items write straight into caller-owned disjoint output
//! regions (via [`DisjointWriter`]) and every worker reuses one scratch
//! arena across all the items it claims.
//!
//! Callers must make the items themselves scheduling-invariant (e.g. the
//! engine's counter-based per-(chunk, column) noise streams) — the pool
//! guarantees only ordering of the result vector, not execution order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Map `f` over `0..n_items` on up to `threads` workers; results are
/// returned in item order. `threads <= 1` (or a single item) runs inline
/// on the caller with zero thread overhead, so a pool of one is exactly
/// the sequential loop.
pub fn parallel_map<T, F>(threads: usize, n_items: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = threads.max(1).min(n_items);
    if workers <= 1 {
        return (0..n_items).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_items {
                    break;
                }
                if tx.send((i, f(i))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
    });

    let mut slots: Vec<Option<T>> = (0..n_items).map(|_| None).collect();
    for (i, v) in rx.iter() {
        slots[i] = Some(v);
    }
    slots
        .into_iter()
        .map(|s| s.expect("worker panicked before finishing its item"))
        .collect()
}

/// Run `f` over `0..n_items` on up to `threads` workers for effect
/// (no result collection). Each worker builds one scratch value with
/// `scratch` when it starts and reuses it — `&mut` — across every item
/// it claims, so per-item heap churn amortizes to zero (the engine's
/// [`WorkerArena`](crate::exec::WorkerArena) accumulator slabs).
///
/// Items are claimed dynamically from a shared atomic counter exactly
/// like [`parallel_map`], so stragglers load-balance; callers that write
/// shared output must do so through provably disjoint regions (see
/// [`DisjointWriter`]). `threads <= 1` (or a single item) runs inline on
/// the caller with one scratch and zero thread overhead.
pub fn parallel_for_with<S, F>(
    threads: usize,
    n_items: usize,
    scratch: impl Fn() -> S + Sync,
    f: F,
) where
    F: Fn(usize, &mut S) + Sync,
{
    let workers = threads.max(1).min(n_items);
    if workers <= 1 {
        let mut s = scratch();
        for i in 0..n_items {
            f(i, &mut s);
        }
        return;
    }

    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            let scratch = &scratch;
            scope.spawn(move || {
                let mut s = scratch();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n_items {
                        break;
                    }
                    f(i, &mut s);
                }
            });
        }
    });
}

/// Shared-mutable access to one output slice for parallel scatter into
/// **disjoint** regions — the zero-copy alternative to collecting
/// per-item `Vec`s and reassembling on the caller.
///
/// The writer pins the slice's pointer and length; workers carve out
/// bounds-checked sub-slices with [`Self::slice_mut`]. Disjointness of
/// concurrently handed-out ranges is the caller's obligation (it cannot
/// be checked cheaply at runtime), which is why `slice_mut` is
/// `unsafe` — the engine's items partition the output by construction
/// ((chunk-row band × column block) regions never overlap).
///
/// Generic over the element type: the engine's pass 1 fills the
/// [`PanelCache`](crate::exec::PanelCache) f64 slab through an
/// `f64` writer under `KernelPrecision::Exact` and the 64-byte-aligned
/// `i16` code slab through an `i16` writer under `Quantized`; pass 2
/// scatters the f64 output either way.
pub struct DisjointWriter<'a, T> {
    ptr: *mut T,
    len: usize,
    _borrow: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: handing `&DisjointWriter` to multiple threads only enables
// `slice_mut`, whose disjointness contract makes concurrent use sound
// for `T: Send` (distinct elements move to distinct threads).
unsafe impl<T: Send> Sync for DisjointWriter<'_, T> {}

impl<'a, T> DisjointWriter<'a, T> {
    /// Borrow `slice` for parallel disjoint writes. The writer holds the
    /// unique borrow, so no safe access to the slice can race it.
    pub fn new(slice: &'a mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _borrow: std::marker::PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The sub-slice `[start, start + len)`, bounds-checked.
    ///
    /// # Safety
    /// Ranges handed to concurrently running callers must be pairwise
    /// disjoint; a range may be revisited only after the call that held
    /// it returned (in the engine: each work item owns its output region
    /// exclusively for the whole parallel pass).
    #[allow(clippy::mut_from_ref)] // shared handle is the whole point; see Safety
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        let end = start.checked_add(len).expect("range overflow");
        assert!(end <= self.len, "range {start}..{end} out of bounds ({})", self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Split `n` items into `parts` near-equal contiguous ranges (the last
/// ranges are one shorter when `n % parts != 0`). Empty ranges are
/// omitted, so the result has `min(parts, n)` entries.
pub fn partition_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::new();
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        if len == 0 {
            break;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order_any_thread_count() {
        let expect: Vec<usize> = (0..100).map(|i| i * i).collect();
        for threads in [1, 2, 4, 8, 32] {
            let got = parallel_map(threads, 100, |i| i * i);
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single_item() {
        let got: Vec<usize> = parallel_map(4, 0, |i| i);
        assert!(got.is_empty());
        let got = parallel_map(4, 1, |i| i + 10);
        assert_eq!(got, vec![10]);
    }

    #[test]
    fn workers_actually_run_concurrently() {
        use std::sync::atomic::AtomicUsize;
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static LIVE: AtomicUsize = AtomicUsize::new(0);
        let _ = parallel_map(4, 16, |_| {
            let live = LIVE.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(live, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(5));
            LIVE.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(PEAK.load(Ordering::SeqCst) > 1, "no overlap observed");
    }

    #[test]
    fn partition_covers_everything() {
        for (n, parts) in [(10, 3), (3, 10), (0, 4), (16, 4), (7, 1)] {
            let ranges = partition_ranges(n, parts);
            let total: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(total, n, "n={n} parts={parts}");
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn partition_zero_items_is_empty() {
        assert!(partition_ranges(0, 1).is_empty());
        assert!(partition_ranges(0, 8).is_empty());
    }

    #[test]
    fn partition_more_workers_than_items_yields_singletons() {
        // 3 items over 10 workers: 3 singleton ranges, no empty ranges
        assert_eq!(partition_ranges(3, 10), vec![0..1, 1..2, 2..3]);
        assert_eq!(partition_ranges(1, 4), vec![0..1]);
    }

    #[test]
    fn partition_exact_division_is_uniform() {
        let ranges = partition_ranges(16, 4);
        assert_eq!(ranges, vec![0..4, 4..8, 8..12, 12..16]);
        assert!(ranges.iter().all(|r| r.len() == 4));
    }

    #[test]
    fn partition_remainder_spreads_over_leading_ranges() {
        // 10 = 4 + 3 + 3: the extra item lands on the first range and
        // range sizes never differ by more than one
        let ranges = partition_ranges(10, 3);
        assert_eq!(ranges, vec![0..4, 4..7, 7..10]);
        let (min, max) = (
            ranges.iter().map(|r| r.len()).min().unwrap(),
            ranges.iter().map(|r| r.len()).max().unwrap(),
        );
        assert!(max - min <= 1);
    }

    #[test]
    fn partition_zero_parts_clamps_to_one() {
        assert_eq!(partition_ranges(5, 0), vec![0..5]);
    }

    #[test]
    fn for_with_scatters_disjoint_regions_any_thread_count() {
        // 64 items, each owning an 8-wide region of one shared output —
        // the exact shape of the engine's pass-2 direct scatter
        let n_items = 64;
        let width = 8;
        for threads in [1, 2, 4, 8, 32] {
            let mut out = vec![0usize; n_items * width];
            let writer = DisjointWriter::new(&mut out);
            parallel_for_with(
                threads,
                n_items,
                || 0usize,
                |i, _| {
                    // SAFETY: item i exclusively owns [i·width, (i+1)·width)
                    let dst = unsafe { writer.slice_mut(i * width, width) };
                    for (t, d) in dst.iter_mut().enumerate() {
                        *d = i * width + t;
                    }
                },
            );
            let want: Vec<usize> = (0..n_items * width).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn for_with_builds_one_scratch_per_worker_not_per_item() {
        static BUILT: AtomicUsize = AtomicUsize::new(0);
        BUILT.store(0, Ordering::SeqCst);
        let hits = AtomicUsize::new(0);
        parallel_for_with(
            4,
            100,
            || {
                BUILT.fetch_add(1, Ordering::SeqCst);
                Vec::<u8>::new()
            },
            |_, s: &mut Vec<u8>| {
                s.push(1); // scratch persists across the worker's items
                hits.fetch_add(1, Ordering::SeqCst);
            },
        );
        assert_eq!(hits.load(Ordering::SeqCst), 100);
        let built = BUILT.load(Ordering::SeqCst);
        assert!(built <= 4, "scratch built {built} times for 4 workers");
    }

    #[test]
    fn for_with_inline_when_single_threaded_or_single_item() {
        let mut out = vec![0u32; 3];
        let writer = DisjointWriter::new(&mut out);
        parallel_for_with(1, 3, || (), |i, _| {
            // SAFETY: singleton regions are disjoint
            unsafe { writer.slice_mut(i, 1) }[0] = i as u32 + 1;
        });
        assert_eq!(out, vec![1, 2, 3]);
        let got: Vec<usize> = parallel_map(8, 1, |i| i + 7);
        assert_eq!(got, vec![7]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn disjoint_writer_bounds_checked() {
        let mut out = vec![0u8; 4];
        let writer = DisjointWriter::new(&mut out);
        // SAFETY: single-threaded; the panic fires before any write
        let _ = unsafe { writer.slice_mut(2, 3) };
    }
}

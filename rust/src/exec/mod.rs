//! Sparsity-compiled parallel execution layer.
//!
//! SCATTER's premise is that pruned rows/columns cost *nothing* — this
//! module makes the digital twin honor that at execution time:
//!
//! * [`plan`] — per-chunk [`ChunkPlan`]s compiled once at programming
//!   time: active-index gather tables and gain-folded dense weight
//!   panels, so the streamed matvec does zero mask branching and skips
//!   pruned work entirely;
//! * [`pool`] — a std-only scoped worker pool ([`parallel_map`]) that
//!   partitions (chunk-row × column-block) work items across threads.
//!
//! Determinism contract: programming is sequential, and all per-cycle
//! noise is drawn from counter-based per-(chunk, column) RNG streams
//! ([`crate::util::XorShiftRng::from_stream`]), so engine outputs are
//! bit-identical for any worker count — asserted in
//! `rust/tests/exec_engine.rs`.

pub mod plan;
pub mod pool;

pub use plan::ChunkPlan;
pub use pool::{parallel_map, partition_ranges};

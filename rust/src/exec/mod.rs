//! Sparsity-compiled parallel execution layer.
//!
//! SCATTER's premise is that pruned rows/columns cost *nothing* — this
//! module makes the digital twin honor that at execution time, and (as
//! of PR 4) that sparsity bookkeeping is paid **once**, never per MAC:
//!
//! * [`plan`] — per-chunk [`ChunkPlan`]s compiled once at programming
//!   time: active-index gather tables and gain-folded weight panels, so
//!   the streamed matvec does zero mask branching and skips pruned work
//!   entirely;
//! * [`kernel`] — the panel micro-kernels the plans compile into: the
//!   bit-exact f64 [`PackedPanel`] (4-row quads × nonzero column runs,
//!   branch-free FMA with a run-compressed tail) and the
//!   integer-quantized [`QuantPanel`] (i16 codes in lane-width row
//!   panels, `i32` SIMD accumulation with one f64 fold per output),
//!   selected by [`KernelPrecision`] with runtime [`SimdLevel`]
//!   detection and a `SCATTER_FORCE_SCALAR=1` override;
//! * [`arena`] — allocation-free steady state: per-worker scratch
//!   ([`WorkerArena`]), the shared quantized-activation panel cache
//!   ([`PanelCache`], f64 slab plus a 64-byte-aligned i16 code slab for
//!   the quantized path) that removes the O(p×) per-chunk-row re-gather
//!   redundancy, and the stage-time instrumentation ([`StageTimes`])
//!   behind `scatter bench engine --stages`;
//! * [`pool`] — a std-only scoped worker pool: [`parallel_map`]
//!   (collects results by index) and [`parallel_for_with`] (worker-local
//!   scratch + direct disjoint-region output via [`DisjointWriter`],
//!   generic over the element type so pass 1 can fill either slab).
//!
//! Determinism contract: programming is sequential, and all per-cycle
//! noise is drawn from counter-based per-(chunk, column) RNG streams
//! ([`crate::util::XorShiftRng::from_stream`]), so engine outputs are
//! bit-identical for any worker count **and** for any split of the work
//! into passes — the two-pass shared-panel path and the single-pass
//! uncached path produce the same bits — asserted in
//! `rust/tests/exec_engine.rs`. The quantized kernel preserves the same
//! invariance (integer sums are order-independent and the per-output
//! fold is unique), just on its own integer grid: `Exact` and
//! `Quantized` differ in rounding, never in determinism.

pub mod arena;
pub mod kernel;
pub mod plan;
pub mod pool;

pub use arena::{PanelCache, StageBreakdown, StageTimes, WorkerArena};
pub use kernel::{
    cpu_features, detected_simd, resolve_simd, CpuFeatures, KernelPrecision,
    PackedPanel, QuantPanel, SimdLevel,
};
pub use plan::ChunkPlan;
pub use pool::{parallel_for_with, parallel_map, partition_ranges, DisjointWriter};

//! Register-blocked panel micro-kernel over a row-run-packed weight
//! panel.
//!
//! [`ChunkPlan::accumulate`](crate::exec::ChunkPlan::accumulate) used to
//! sweep the gain-folded panel one row at a time with an
//! `if wv == 0.0 { continue }` branch per weight — every output row
//! re-streamed the whole `xq` panel from memory, and quantized-to-zero
//! weights still cost control flow. [`PackedPanel`] compiles the panel
//! once (at `ChunkPlan::from_blocks` time) into the shape the hot loop
//! wants:
//!
//! * **4-row register tiles** — exec rows are grouped into quads; the
//!   inner loop loads each `xq` row once and FMAs it into four
//!   accumulator rows, quartering the activation-panel traffic;
//! * **row-run packing** — per quad, maximal column runs where at least
//!   one of the four rows is nonzero are recorded as `(col0, len)` runs
//!   with their weights packed contiguously (`[w0 w1 w2 w3]` per
//!   column), so all-zero column spans are compiled out and the inner
//!   loop is branch-free FMA over contiguous `w` and `xq`;
//! * **scalar tail** — the `nrows % 4` leftover rows keep the
//!   one-row-at-a-time sweep (dense, zero-skipping), bounding the
//!   padding waste at zero.
//!
//! Numerical contract: for every output element the MAC terms are added
//! in ascending active-column order, exactly like the scalar sweep, so
//! planned-vs-reference equivalence is preserved across all mask modes
//! (asserted in `rust/tests/exec_engine.rs`). The only difference is
//! that a quad adds `0.0 · x` terms for columns where *some* of its four
//! rows are zero — an exact no-op for finite activations.

/// One maximal nonzero column run of a 4-row quad.
#[derive(Debug, Clone)]
struct Run {
    /// First panel column of the run.
    col0: u32,
    /// Number of consecutive columns.
    len: u32,
    /// Offset of the run's packed weights in `w_packed`
    /// (`len × 4` values, column-major: `[ci][row_in_quad]`).
    w_off: u32,
}

/// A weight panel packed for the register-blocked kernel. Logical shape
/// is `nrows × ncols` (exec rows × active columns), identical to the
/// dense panel it was packed from.
#[derive(Debug, Clone, Default)]
pub struct PackedPanel {
    nrows: usize,
    ncols: usize,
    /// Per full quad: `(offset, count)` into `runs`.
    quads: Vec<(u32, u32)>,
    runs: Vec<Run>,
    /// Packed quad weights, run-major; within a run, `[ci][0..4]`.
    w_packed: Vec<f64>,
    /// Dense scalar-tail rows (`nrows % 4` of them), row-major `ncols`.
    tail: Vec<f64>,
}

impl PackedPanel {
    /// Pack a dense row-major `nrows × ncols` panel.
    pub fn pack(w: &[f64], nrows: usize, ncols: usize) -> Self {
        assert_eq!(w.len(), nrows * ncols);
        let nquads = nrows / 4;
        let mut quads = Vec::with_capacity(nquads);
        let mut runs = Vec::new();
        let mut w_packed = Vec::new();
        for qd in 0..nquads {
            let base = qd * 4;
            let run0 = runs.len() as u32;
            let mut ci = 0;
            while ci < ncols {
                // skip columns where the whole quad is zero
                let live =
                    |ci: usize| (0..4).any(|k| w[(base + k) * ncols + ci] != 0.0);
                if !live(ci) {
                    ci += 1;
                    continue;
                }
                let col0 = ci;
                let w_off = w_packed.len() as u32;
                while ci < ncols && live(ci) {
                    for k in 0..4 {
                        w_packed.push(w[(base + k) * ncols + ci]);
                    }
                    ci += 1;
                }
                runs.push(Run { col0: col0 as u32, len: (ci - col0) as u32, w_off });
            }
            quads.push((run0, runs.len() as u32 - run0));
        }
        let tail = w[nquads * 4 * ncols..].to_vec();
        Self { nrows, ncols, quads, runs, w_packed, tail }
    }

    /// Logical (rows, cols) of the packed panel.
    pub fn dims(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    /// Panel columns the quad kernel actually visits (Σ run lengths over
    /// all quads) — all-zero spans are compiled out of this count.
    pub fn packed_cols(&self) -> usize {
        self.runs.iter().map(|r| r.len as usize).sum()
    }

    /// Accumulate `panel × xq` into `buf`.
    ///
    /// `xq` is the activation panel, `ncols × bcols` row-major. `rows`
    /// maps exec row `ri` to its destination row in `buf` (chunk-local,
    /// stride `bcols`, strictly ascending — the [`ChunkPlan`] gather
    /// table).
    ///
    /// [`ChunkPlan`]: crate::exec::ChunkPlan
    pub fn accumulate(&self, xq: &[f64], bcols: usize, buf: &mut [f64], rows: &[u32]) {
        debug_assert_eq!(rows.len(), self.nrows);
        debug_assert_eq!(xq.len(), self.ncols * bcols);
        let nquads = self.nrows / 4;
        for (qd, &(run0, nruns)) in self.quads.iter().enumerate() {
            let r = [
                rows[qd * 4] as usize,
                rows[qd * 4 + 1] as usize,
                rows[qd * 4 + 2] as usize,
                rows[qd * 4 + 3] as usize,
            ];
            let [d0, d1, d2, d3] = four_rows(buf, bcols, r);
            for run in &self.runs[run0 as usize..(run0 + nruns) as usize] {
                let mut wo = run.w_off as usize;
                for ci in run.col0 as usize..(run.col0 + run.len) as usize {
                    let xrow = &xq[ci * bcols..ci * bcols + bcols];
                    let (w0, w1, w2, w3) = (
                        self.w_packed[wo],
                        self.w_packed[wo + 1],
                        self.w_packed[wo + 2],
                        self.w_packed[wo + 3],
                    );
                    wo += 4;
                    for t in 0..bcols {
                        let xv = xrow[t];
                        d0[t] += w0 * xv;
                        d1[t] += w1 * xv;
                        d2[t] += w2 * xv;
                        d3[t] += w3 * xv;
                    }
                }
            }
        }
        // scalar tail: the 0..3 rows a quad cannot cover
        for ri in nquads * 4..self.nrows {
            let row = rows[ri] as usize;
            let dst = &mut buf[row * bcols..row * bcols + bcols];
            let wrow = &self.tail[(ri - nquads * 4) * self.ncols..][..self.ncols];
            for (ci, &wv) in wrow.iter().enumerate() {
                if wv == 0.0 {
                    continue;
                }
                let xrow = &xq[ci * bcols..(ci + 1) * bcols];
                for (d, &xv) in dst.iter_mut().zip(xrow) {
                    *d += wv * xv;
                }
            }
        }
    }
}

/// Split four disjoint `bcols`-wide destination rows out of `buf`
/// (row offsets strictly ascending), all exactly `bcols` long so the
/// kernel's bounds checks vanish in release builds.
fn four_rows(buf: &mut [f64], bcols: usize, r: [usize; 4]) -> [&mut [f64]; 4] {
    debug_assert!(r[0] < r[1] && r[1] < r[2] && r[2] < r[3]);
    let (a, rest) = buf.split_at_mut(r[1] * bcols);
    let (b, rest) = rest.split_at_mut((r[2] - r[1]) * bcols);
    let (c, d) = rest.split_at_mut((r[3] - r[2]) * bcols);
    [
        &mut a[r[0] * bcols..(r[0] + 1) * bcols],
        &mut b[..bcols],
        &mut c[..bcols],
        &mut d[..bcols],
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShiftRng;

    /// The scalar oracle: one row at a time, zero-skipping — the exact
    /// pre-PR4 `ChunkPlan::accumulate` inner sweep.
    fn naive(
        w: &[f64],
        ncols: usize,
        xq: &[f64],
        bcols: usize,
        buf: &mut [f64],
        rows: &[u32],
    ) {
        for (ri, &row) in rows.iter().enumerate() {
            let dst = &mut buf[row as usize * bcols..row as usize * bcols + bcols];
            let wrow = &w[ri * ncols..(ri + 1) * ncols];
            for (ci, &wv) in wrow.iter().enumerate() {
                if wv == 0.0 {
                    continue;
                }
                let xrow = &xq[ci * bcols..(ci + 1) * bcols];
                for (d, &xv) in dst.iter_mut().zip(xrow) {
                    *d += wv * xv;
                }
            }
        }
    }

    fn random_panel(
        nrows: usize,
        ncols: usize,
        zero_frac: f64,
        rng: &mut XorShiftRng,
    ) -> Vec<f64> {
        (0..nrows * ncols)
            .map(|_| {
                if rng.uniform() < zero_frac {
                    0.0
                } else {
                    rng.uniform() * 2.0 - 1.0
                }
            })
            .collect()
    }

    #[test]
    fn packed_kernel_matches_scalar_sweep() {
        let mut rng = XorShiftRng::new(42);
        for &(nrows, ncols) in
            &[(0, 5), (1, 7), (3, 4), (4, 9), (5, 1), (8, 16), (11, 13), (16, 64)]
        {
            for &bcols in &[1usize, 2, 5, 8] {
                for &zero_frac in &[0.0, 0.3, 0.9] {
                    let w = random_panel(nrows, ncols, zero_frac, &mut rng);
                    // sparse ascending destination-row table with gaps
                    let rows: Vec<u32> = (0..nrows as u32).map(|i| i * 2 + 1).collect();
                    let buf_rows = nrows * 2 + 2;
                    let mut xq = vec![0.0; ncols * bcols];
                    rng.fill_uniform(&mut xq, 0.0, 1.0);

                    let mut want = vec![0.0; buf_rows * bcols];
                    naive(&w, ncols, &xq, bcols, &mut want, &rows);

                    let panel = PackedPanel::pack(&w, nrows, ncols);
                    assert_eq!(panel.dims(), (nrows, ncols));
                    let mut got = vec![0.0; buf_rows * bcols];
                    panel.accumulate(&xq, bcols, &mut got, &rows);

                    for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
                        assert!(
                            (g - w_).abs() < 1e-12,
                            "{nrows}x{ncols} b={bcols} z={zero_frac} idx {i}: {g} vs {w_}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn zero_column_spans_are_compiled_out() {
        // 4 rows × 16 cols with columns 4..12 all-zero: one quad, two
        // runs, and the packed column count excludes the dead span
        let mut w = vec![1.0; 4 * 16];
        for row in 0..4 {
            for ci in 4..12 {
                w[row * 16 + ci] = 0.0;
            }
        }
        let panel = PackedPanel::pack(&w, 4, 16);
        assert_eq!(panel.quads.len(), 1);
        assert_eq!(panel.quads[0].1, 2, "two runs around the zero span");
        assert_eq!(panel.packed_cols(), 8, "8 of 16 columns survive packing");
    }

    #[test]
    fn all_zero_panel_has_no_runs() {
        let w = vec![0.0; 8 * 6];
        let panel = PackedPanel::pack(&w, 8, 6);
        assert_eq!(panel.packed_cols(), 0);
        let xq = vec![1.0; 6 * 3];
        let rows: Vec<u32> = (0..8).collect();
        let mut buf = vec![0.0; 8 * 3];
        panel.accumulate(&xq, 3, &mut buf, &rows);
        assert!(buf.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn empty_panel_is_a_noop() {
        let panel = PackedPanel::pack(&[], 0, 0);
        assert_eq!(panel.dims(), (0, 0));
        let mut buf: Vec<f64> = Vec::new();
        panel.accumulate(&[], 1, &mut buf, &[]);
    }
}

//! Register-blocked panel micro-kernels over row-run-packed weight
//! panels: the bit-exact f64 quad kernel ([`PackedPanel`]) and the
//! integer-quantized SIMD kernel ([`QuantPanel`]).
//!
//! [`ChunkPlan::accumulate`](crate::exec::ChunkPlan::accumulate) used to
//! sweep the gain-folded panel one row at a time with an
//! `if wv == 0.0 { continue }` branch per weight — every output row
//! re-streamed the whole `xq` panel from memory, and quantized-to-zero
//! weights still cost control flow. [`PackedPanel`] compiles the panel
//! once (at `ChunkPlan::from_blocks` time) into the shape the hot loop
//! wants:
//!
//! * **4-row register tiles** — exec rows are grouped into quads; the
//!   inner loop loads each `xq` row once and FMAs it into four
//!   accumulator rows, quartering the activation-panel traffic;
//! * **row-run packing** — per quad, maximal column runs where at least
//!   one of the four rows is nonzero are recorded as `(col0, len)` runs
//!   with their weights packed contiguously (`[w0 w1 w2 w3]` per
//!   column), so all-zero column spans are compiled out and the inner
//!   loop is branch-free FMA over contiguous `w` and `xq`;
//! * **run-compressed tail** — the `nrows % 4` leftover rows get the
//!   same maximal-nonzero-run treatment per row (weight stride 1), so
//!   masked-out column spans are compiled out of the tail too instead
//!   of being stored dense and re-tested per sweep.
//!
//! Numerical contract: for every output element the MAC terms are added
//! in ascending active-column order, exactly like the scalar sweep, so
//! planned-vs-reference equivalence is preserved across all mask modes
//! (asserted in `rust/tests/exec_engine.rs`). The only difference is
//! that a quad adds `0.0 · x` terms for columns where *some* of its four
//! rows are zero — an exact no-op for finite activations.
//!
//! # The integer-quantized SIMD kernel
//!
//! SCATTER's activations are normalized to `[0, 1]` per column block
//! before they hit the crossbar, so the host-side sweep can run on
//! narrow integer lanes. [`QuantPanel`] re-quantizes the gain-folded
//! weight panel to `i16` codes (per-exec-row symmetric scale,
//! `|code| <= 127`), and the engine's pass 1 materializes activations as
//! `i16` codes on a 0..=1023 grid ([`ACT_LEVELS`]). The sweep then
//! accumulates `w_code * x_code` products in `i32` and rescales to f64
//! exactly once per (row, streamed column) with the fused per-row factor
//! `row_scale = (max|w| / 127) / 1023`.
//!
//! Overflow headroom: `|acc| <= ncols * 127 * 1023 ≈ ncols * 1.3e5`, so
//! `i32` is safe for panels up to ~16k active columns; the execution
//! engine's column blocking caps active columns per chunk at the chunk
//! width (64 under the default config), leaving >250x margin.
//!
//! Rows are grouped into lane-width panels (8 for AVX2, 16 when AVX-512
//! is detected) and swept with stable `core::arch::x86_64` AVX2
//! intrinsics — 16-row panels run as two 8-row banks of 256-bit `i32`
//! accumulators, which halves the run-table bookkeeping without
//! requiring AVX-512 intrinsics. The scalar integer sweep
//! (`accumulate` with [`SimdLevel::Scalar`]) computes the *same* `i32`
//! sums (integer addition is order-independent) followed by the same
//! single f64 fold, so `simd == scalar` holds **exactly**, making the
//! scalar path both the portable fallback and the equivalence oracle.
//!
//! Variant selection is runtime-detected (`is_x86_feature_detected!`),
//! cached once per process, and overridable with `SCATTER_FORCE_SCALAR=1`
//! (see [`detected_simd`]). [`KernelPrecision`] selects between the
//! bit-exact f64 path (`Exact`, the default — every e2e bit-identity
//! suite pins it) and the integer path (`Quantized`, gated by an
//! argmax-agreement property test and measured by the bench sweeps).

use std::sync::OnceLock;

/// Activation integer grid for the quantized kernel: codes span
/// `0..=1023` (10-bit), a superset of the 6-bit DAC grid the exact path
/// models, so DAC-quantized activations round-trip losslessly.
pub const ACT_LEVELS: f64 = 1023.0;

/// Weight code range for the quantized kernel: `|code| <= 127`.
const W_LEVELS: f64 = 127.0;

/// Kernel numeric mode for the execution engine.
///
/// `Exact` (default) runs the f64 quad kernel and keeps the bit-identity
/// guarantees every e2e suite pins (batch, chaos, swap, repair).
/// `Quantized` runs the integer SIMD kernel: activations and weights are
/// re-quantized to integer codes and accumulated in `i32`, which changes
/// rounding — it is gated by an argmax-agreement (>= 0.99 vs `Exact`)
/// property test and is what the bench sweeps measure.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelPrecision {
    /// Bit-exact f64 quad kernel (default).
    #[default]
    Exact,
    /// Integer-quantized SIMD kernel (i16 codes, i32 accumulation).
    Quantized,
}

impl KernelPrecision {
    /// Canonical lowercase name (`"exact"` / `"quantized"`), as accepted
    /// by `--precision` and the `ServerConfig` JSON field.
    pub fn as_str(self) -> &'static str {
        match self {
            KernelPrecision::Exact => "exact",
            KernelPrecision::Quantized => "quantized",
        }
    }
}

impl std::str::FromStr for KernelPrecision {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "exact" => Ok(KernelPrecision::Exact),
            "quantized" => Ok(KernelPrecision::Quantized),
            other => Err(format!(
                "unknown precision '{other}' (expected 'exact' or 'quantized')"
            )),
        }
    }
}

/// CPU SIMD features relevant to the quantized kernel, as detected at
/// runtime (all `false` off x86_64). Recorded in every BENCH_*.json
/// artifact so CI floors are interpretable per machine.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CpuFeatures {
    pub avx2: bool,
    pub avx512f: bool,
    pub fma: bool,
}

/// Detect SIMD features on the running CPU. `std` caches the underlying
/// CPUID queries, so this is cheap to call repeatedly.
pub fn cpu_features() -> CpuFeatures {
    #[cfg(target_arch = "x86_64")]
    {
        CpuFeatures {
            avx2: is_x86_feature_detected!("avx2"),
            avx512f: is_x86_feature_detected!("avx512f"),
            fma: is_x86_feature_detected!("fma"),
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        CpuFeatures::default()
    }
}

/// Active SIMD variant of the quantized kernel. Ordered by capability:
/// an override can only lower the level below what the CPU supports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    /// Portable scalar integer sweep — fallback and equivalence oracle.
    Scalar,
    /// AVX2: 8-row panels, 8 streamed columns per 256-bit register.
    Avx2,
    /// AVX-512-capable host: 16-row panels swept as two 8-row AVX2
    /// banks (stable intrinsics only) — halves run-table bookkeeping.
    Avx512,
}

impl SimdLevel {
    /// Variant label recorded in bench artifacts and `/metrics`.
    pub fn as_str(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512 => "avx512",
        }
    }

    /// Row-panel height the variant packs for (the lane width).
    pub fn lanes(self) -> usize {
        match self {
            SimdLevel::Scalar | SimdLevel::Avx2 => 8,
            SimdLevel::Avx512 => 16,
        }
    }
}

/// Pure variant-resolution policy: scalar when forced or when the CPU
/// lacks AVX2; widest otherwise. Split from [`detected_simd`] so the
/// policy is unit-testable without mutating process env.
pub fn resolve_simd(force_scalar: bool, f: CpuFeatures) -> SimdLevel {
    if force_scalar || !f.avx2 {
        SimdLevel::Scalar
    } else if f.avx512f {
        SimdLevel::Avx512
    } else {
        SimdLevel::Avx2
    }
}

/// `SCATTER_FORCE_SCALAR` parse: `1` or `true` (any case) forces the
/// scalar kernel.
fn env_forces_scalar(v: Option<&str>) -> bool {
    matches!(v, Some(s) if s == "1" || s.eq_ignore_ascii_case("true"))
}

/// The process-wide SIMD variant: runtime feature detection combined
/// with the `SCATTER_FORCE_SCALAR` env override, resolved once and
/// cached (the env var is read a single time per process — use the
/// engine's programmatic override to switch variants within a process,
/// e.g. for the `simd_vs_scalar` bench cell).
pub fn detected_simd() -> SimdLevel {
    static CACHE: OnceLock<SimdLevel> = OnceLock::new();
    *CACHE.get_or_init(|| {
        let force = std::env::var("SCATTER_FORCE_SCALAR")
            .ok()
            .map(|v| env_forces_scalar(Some(v.as_str())))
            .unwrap_or(false);
        resolve_simd(force, cpu_features())
    })
}

/// One maximal nonzero column run of a row group (quad, lane panel, or
/// single tail row — the weight stride per column is the group height).
#[derive(Debug, Clone)]
struct Run {
    /// First panel column of the run.
    col0: u32,
    /// Number of consecutive columns.
    len: u32,
    /// Offset of the run's packed weights (`len × group_height` values,
    /// column-major: `[ci][row_in_group]`).
    w_off: u32,
}

/// A weight panel packed for the register-blocked kernel. Logical shape
/// is `nrows × ncols` (exec rows × active columns), identical to the
/// dense panel it was packed from.
#[derive(Debug, Clone, Default)]
pub struct PackedPanel {
    nrows: usize,
    ncols: usize,
    /// Per full quad: `(offset, count)` into `runs`.
    quads: Vec<(u32, u32)>,
    runs: Vec<Run>,
    /// Packed weights, run-major; quad runs store `[ci][0..4]`, tail
    /// runs store one weight per column.
    w_packed: Vec<f64>,
    /// Per tail row (`nrows % 4` of them): `(offset, count)` into
    /// `runs`, weight stride 1.
    tail_rows: Vec<(u32, u32)>,
}

impl PackedPanel {
    /// Pack a dense row-major `nrows × ncols` panel.
    pub fn pack(w: &[f64], nrows: usize, ncols: usize) -> Self {
        assert_eq!(w.len(), nrows * ncols);
        let nquads = nrows / 4;
        let mut quads = Vec::with_capacity(nquads);
        let mut runs = Vec::new();
        let mut w_packed = Vec::new();
        for qd in 0..nquads {
            let base = qd * 4;
            let run0 = runs.len() as u32;
            let mut ci = 0;
            while ci < ncols {
                // skip columns where the whole quad is zero
                let live =
                    |ci: usize| (0..4).any(|k| w[(base + k) * ncols + ci] != 0.0);
                if !live(ci) {
                    ci += 1;
                    continue;
                }
                let col0 = ci;
                let w_off = w_packed.len() as u32;
                while ci < ncols && live(ci) {
                    for k in 0..4 {
                        w_packed.push(w[(base + k) * ncols + ci]);
                    }
                    ci += 1;
                }
                runs.push(Run { col0: col0 as u32, len: (ci - col0) as u32, w_off });
            }
            quads.push((run0, runs.len() as u32 - run0));
        }
        // run-compress the 0..3 leftover rows too (weight stride 1), so
        // masked-out spans cost nothing in the tail either
        let mut tail_rows = Vec::with_capacity(nrows - nquads * 4);
        for ri in nquads * 4..nrows {
            let run0 = runs.len() as u32;
            let wrow = &w[ri * ncols..(ri + 1) * ncols];
            let mut ci = 0;
            while ci < ncols {
                if wrow[ci] == 0.0 {
                    ci += 1;
                    continue;
                }
                let col0 = ci;
                let w_off = w_packed.len() as u32;
                while ci < ncols && wrow[ci] != 0.0 {
                    w_packed.push(wrow[ci]);
                    ci += 1;
                }
                runs.push(Run { col0: col0 as u32, len: (ci - col0) as u32, w_off });
            }
            tail_rows.push((run0, runs.len() as u32 - run0));
        }
        Self { nrows, ncols, quads, runs, w_packed, tail_rows }
    }

    /// Logical (rows, cols) of the packed panel.
    pub fn dims(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    /// Panel columns the kernel actually visits (Σ run lengths over all
    /// quads and tail rows) — all-zero spans are compiled out of this
    /// count.
    pub fn packed_cols(&self) -> usize {
        self.runs.iter().map(|r| r.len as usize).sum()
    }

    /// Accumulate `panel × xq` into `buf`.
    ///
    /// `xq` is the activation panel, `ncols × bcols` row-major. `rows`
    /// maps exec row `ri` to its destination row in `buf` (chunk-local,
    /// stride `bcols`, strictly ascending — the [`ChunkPlan`] gather
    /// table).
    ///
    /// [`ChunkPlan`]: crate::exec::ChunkPlan
    pub fn accumulate(&self, xq: &[f64], bcols: usize, buf: &mut [f64], rows: &[u32]) {
        debug_assert_eq!(rows.len(), self.nrows);
        debug_assert_eq!(xq.len(), self.ncols * bcols);
        let nquads = self.nrows / 4;
        for (qd, &(run0, nruns)) in self.quads.iter().enumerate() {
            let r = [
                rows[qd * 4] as usize,
                rows[qd * 4 + 1] as usize,
                rows[qd * 4 + 2] as usize,
                rows[qd * 4 + 3] as usize,
            ];
            let [d0, d1, d2, d3] = four_rows(buf, bcols, r);
            for run in &self.runs[run0 as usize..(run0 + nruns) as usize] {
                let mut wo = run.w_off as usize;
                for ci in run.col0 as usize..(run.col0 + run.len) as usize {
                    let xrow = &xq[ci * bcols..ci * bcols + bcols];
                    let (w0, w1, w2, w3) = (
                        self.w_packed[wo],
                        self.w_packed[wo + 1],
                        self.w_packed[wo + 2],
                        self.w_packed[wo + 3],
                    );
                    wo += 4;
                    for t in 0..bcols {
                        let xv = xrow[t];
                        d0[t] += w0 * xv;
                        d1[t] += w1 * xv;
                        d2[t] += w2 * xv;
                        d3[t] += w3 * xv;
                    }
                }
            }
        }
        // run-compressed tail: the 0..3 rows a quad cannot cover
        for (k, &(run0, nruns)) in self.tail_rows.iter().enumerate() {
            let row = rows[nquads * 4 + k] as usize;
            let dst = &mut buf[row * bcols..row * bcols + bcols];
            for run in &self.runs[run0 as usize..(run0 + nruns) as usize] {
                let mut wo = run.w_off as usize;
                for ci in run.col0 as usize..(run.col0 + run.len) as usize {
                    let wv = self.w_packed[wo];
                    wo += 1;
                    let xrow = &xq[ci * bcols..(ci + 1) * bcols];
                    for (d, &xv) in dst.iter_mut().zip(xrow) {
                        *d += wv * xv;
                    }
                }
            }
        }
    }
}

/// Split four disjoint `bcols`-wide destination rows out of `buf`
/// (row offsets strictly ascending), all exactly `bcols` long so the
/// kernel's bounds checks vanish in release builds.
fn four_rows(buf: &mut [f64], bcols: usize, r: [usize; 4]) -> [&mut [f64]; 4] {
    debug_assert!(r[0] < r[1] && r[1] < r[2] && r[2] < r[3]);
    let (a, rest) = buf.split_at_mut(r[1] * bcols);
    let (b, rest) = rest.split_at_mut((r[2] - r[1]) * bcols);
    let (c, d) = rest.split_at_mut((r[3] - r[2]) * bcols);
    [
        &mut a[r[0] * bcols..(r[0] + 1) * bcols],
        &mut b[..bcols],
        &mut c[..bcols],
        &mut d[..bcols],
    ]
}

/// Shared read-only context for a quantized sweep: the `i16` activation
/// panel (`ncols × bcols` row-major), its streamed width, and the
/// gather table.
struct SweepCtx<'a> {
    xq: &'a [i16],
    bcols: usize,
    rows: &'a [u32],
}

/// The gain-folded weight panel re-quantized to `i16` codes and packed
/// into lane-width row panels for the integer SIMD sweep. Same run
/// compression as [`PackedPanel`] (liveness judged on the *codes*, so
/// weights that quantize to zero are compiled out too); leftover rows
/// (`nrows % lanes`) are run-compressed per row at weight stride 1.
#[derive(Debug, Clone, Default)]
pub struct QuantPanel {
    nrows: usize,
    ncols: usize,
    /// Row-panel height (8 for AVX2, 16 for AVX-512 hosts); 0 only in
    /// the empty `Default` panel.
    lanes: usize,
    /// Per full lane panel: `(offset, count)` into `runs`, weight
    /// stride `lanes`.
    panels: Vec<(u32, u32)>,
    runs: Vec<Run>,
    /// Per tail row: `(offset, count)` into `runs`, weight stride 1.
    tail_rows: Vec<(u32, u32)>,
    /// Packed weight codes, run-major; panel runs store
    /// `[ci][0..lanes]`, tail runs one code per column.
    wq: Vec<i16>,
    /// Fused per-exec-row rescale `(max|w| / 127) / 1023` applied once
    /// per (row, streamed column) after `i32` accumulation; 0.0 for
    /// all-zero rows (skipped by both sweeps).
    row_scale: Vec<f64>,
}

impl QuantPanel {
    /// Quantize and pack a dense row-major `nrows × ncols` panel for the
    /// given lane width (8 or 16).
    pub fn pack(w: &[f64], nrows: usize, ncols: usize, lanes: usize) -> Self {
        assert!(lanes == 8 || lanes == 16, "lane width must be 8 or 16");
        assert_eq!(w.len(), nrows * ncols);
        debug_assert!(ncols <= 16_000, "i32 accumulator headroom (module doc)");
        let mut row_scale = Vec::with_capacity(nrows);
        let mut codes: Vec<i16> = vec![0; nrows * ncols];
        for ri in 0..nrows {
            let wrow = &w[ri * ncols..(ri + 1) * ncols];
            let wmax = wrow.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
            if wmax == 0.0 {
                row_scale.push(0.0);
                continue;
            }
            let sw = wmax / W_LEVELS;
            for (ci, &wv) in wrow.iter().enumerate() {
                codes[ri * ncols + ci] = (wv / sw).round() as i16;
            }
            row_scale.push(sw / ACT_LEVELS);
        }
        let npanels = nrows / lanes;
        let mut panels = Vec::with_capacity(npanels);
        let mut runs = Vec::new();
        let mut wq = Vec::new();
        for pi in 0..npanels {
            let base = pi * lanes;
            let run0 = runs.len() as u32;
            let mut ci = 0;
            while ci < ncols {
                let live =
                    |ci: usize| (0..lanes).any(|k| codes[(base + k) * ncols + ci] != 0);
                if !live(ci) {
                    ci += 1;
                    continue;
                }
                let col0 = ci;
                let w_off = wq.len() as u32;
                while ci < ncols && live(ci) {
                    for k in 0..lanes {
                        wq.push(codes[(base + k) * ncols + ci]);
                    }
                    ci += 1;
                }
                runs.push(Run { col0: col0 as u32, len: (ci - col0) as u32, w_off });
            }
            panels.push((run0, runs.len() as u32 - run0));
        }
        let mut tail_rows = Vec::with_capacity(nrows - npanels * lanes);
        for ri in npanels * lanes..nrows {
            let run0 = runs.len() as u32;
            let crow = &codes[ri * ncols..(ri + 1) * ncols];
            let mut ci = 0;
            while ci < ncols {
                if crow[ci] == 0 {
                    ci += 1;
                    continue;
                }
                let col0 = ci;
                let w_off = wq.len() as u32;
                while ci < ncols && crow[ci] != 0 {
                    wq.push(crow[ci]);
                    ci += 1;
                }
                runs.push(Run { col0: col0 as u32, len: (ci - col0) as u32, w_off });
            }
            tail_rows.push((run0, runs.len() as u32 - run0));
        }
        Self { nrows, ncols, lanes, panels, runs, tail_rows, wq, row_scale }
    }

    /// Logical (rows, cols) of the packed panel.
    pub fn dims(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    /// Row-panel height the panel was packed for.
    pub fn lane_width(&self) -> usize {
        self.lanes
    }

    /// Panel columns the integer kernel actually visits (Σ run lengths).
    pub fn packed_cols(&self) -> usize {
        self.runs.iter().map(|r| r.len as usize).sum()
    }

    /// Accumulate the dequantized `panel × xq` product into the f64
    /// `buf`, dispatching on `level` (clamped to what the CPU supports).
    ///
    /// `xq` holds activation codes on the [`ACT_LEVELS`] grid,
    /// `ncols × bcols` row-major; `rows` is the [`ChunkPlan`] gather
    /// table, exactly as for [`PackedPanel::accumulate`]. Scalar and
    /// SIMD levels produce bit-identical output: both compute the full
    /// `i32` dot product per (row, streamed column), then apply the same
    /// single `acc as f64 * row_scale` fold.
    ///
    /// [`ChunkPlan`]: crate::exec::ChunkPlan
    pub fn accumulate(
        &self,
        xq: &[i16],
        bcols: usize,
        buf: &mut [f64],
        rows: &[u32],
        level: SimdLevel,
    ) {
        debug_assert_eq!(rows.len(), self.nrows);
        debug_assert_eq!(xq.len(), self.ncols * bcols);
        if self.nrows == 0 || self.ncols == 0 || bcols == 0 {
            return;
        }
        let ctx = SweepCtx { xq, bcols, rows };
        #[cfg(target_arch = "x86_64")]
        if level != SimdLevel::Scalar && cpu_features().avx2 {
            // SAFETY: AVX2 availability is runtime-checked above.
            unsafe { self.accumulate_avx2(&ctx, buf) };
            return;
        }
        #[cfg(not(target_arch = "x86_64"))]
        let _ = level;
        for pi in 0..self.panels.len() {
            self.panel_rows_scalar(&ctx, buf, pi, 0, bcols);
        }
        self.tail_rows_scalar(&ctx, buf, 0, bcols);
    }

    /// Scalar integer sweep of one full lane panel over streamed columns
    /// `[t0, t1)`: exact `i32` sums per (row, column) in 64-column
    /// tiles, one f64 fold each. Shared by the portable path and the
    /// SIMD path's streamed-column remainder.
    fn panel_rows_scalar(
        &self,
        ctx: &SweepCtx,
        buf: &mut [f64],
        pi: usize,
        t0: usize,
        t1: usize,
    ) {
        let l = self.lanes;
        let (run0, nruns) = self.panels[pi];
        let runs = &self.runs[run0 as usize..(run0 + nruns) as usize];
        for r in 0..l {
            let ri = pi * l + r;
            let fr = self.row_scale[ri];
            if fr == 0.0 {
                continue;
            }
            let drow = ctx.rows[ri] as usize * ctx.bcols;
            let mut ta = t0;
            while ta < t1 {
                let tw = (t1 - ta).min(64);
                let mut acc = [0i32; 64];
                for run in runs {
                    let mut wo = run.w_off as usize + r;
                    for ci in run.col0 as usize..(run.col0 + run.len) as usize {
                        let wv = self.wq[wo] as i32;
                        wo += l;
                        if wv == 0 {
                            continue;
                        }
                        let xrow = &ctx.xq[ci * ctx.bcols + ta..][..tw];
                        for (a, &x) in acc[..tw].iter_mut().zip(xrow) {
                            *a += wv * x as i32;
                        }
                    }
                }
                let dst = &mut buf[drow + ta..drow + ta + tw];
                for (d, &a) in dst.iter_mut().zip(&acc[..tw]) {
                    *d += a as f64 * fr;
                }
                ta += tw;
            }
        }
    }

    /// Scalar integer sweep of the `nrows % lanes` tail rows (weight
    /// stride 1) over streamed columns `[t0, t1)`.
    fn tail_rows_scalar(
        &self,
        ctx: &SweepCtx,
        buf: &mut [f64],
        t0: usize,
        t1: usize,
    ) {
        let base = self.panels.len() * self.lanes;
        for (k, &(run0, nruns)) in self.tail_rows.iter().enumerate() {
            let ri = base + k;
            let fr = self.row_scale[ri];
            if fr == 0.0 {
                continue;
            }
            let runs = &self.runs[run0 as usize..(run0 + nruns) as usize];
            let drow = ctx.rows[ri] as usize * ctx.bcols;
            let mut ta = t0;
            while ta < t1 {
                let tw = (t1 - ta).min(64);
                let mut acc = [0i32; 64];
                for run in runs {
                    let mut wo = run.w_off as usize;
                    for ci in run.col0 as usize..(run.col0 + run.len) as usize {
                        let wv = self.wq[wo] as i32;
                        wo += 1;
                        let xrow = &ctx.xq[ci * ctx.bcols + ta..][..tw];
                        for (a, &x) in acc[..tw].iter_mut().zip(xrow) {
                            *a += wv * x as i32;
                        }
                    }
                }
                let dst = &mut buf[drow + ta..drow + ta + tw];
                for (d, &a) in dst.iter_mut().zip(&acc[..tw]) {
                    *d += a as f64 * fr;
                }
                ta += tw;
            }
        }
    }

    /// AVX2 sweep: per 8-row bank, 8 streamed columns per 256-bit `i32`
    /// accumulator register (16-lane panels run two banks). The
    /// streamed-column remainder (`bcols % 8`) and the tail rows reuse
    /// the scalar integer sweep — same `i32` sums, same fold.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available on the running CPU.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn accumulate_avx2(&self, ctx: &SweepCtx, buf: &mut [f64]) {
        use core::arch::x86_64::*;
        let l = self.lanes;
        let bcols = ctx.bcols;
        let t8 = bcols - bcols % 8;
        for (pi, &(run0, nruns)) in self.panels.iter().enumerate() {
            let runs = &self.runs[run0 as usize..(run0 + nruns) as usize];
            for bank in 0..l / 8 {
                let base = pi * l + bank * 8;
                let mut t0 = 0;
                while t0 < t8 {
                    let mut acc = [_mm256_setzero_si256(); 8];
                    for run in runs {
                        let mut wo = run.w_off as usize + bank * 8;
                        for ci in run.col0 as usize..(run.col0 + run.len) as usize {
                            let xv = _mm256_cvtepi16_epi32(_mm_loadu_si128(
                                ctx.xq.as_ptr().add(ci * bcols + t0) as *const __m128i,
                            ));
                            let wcol = &self.wq[wo..wo + 8];
                            for (a, &wv) in acc.iter_mut().zip(wcol) {
                                let wb = _mm256_set1_epi32(wv as i32);
                                *a = _mm256_add_epi32(*a, _mm256_mullo_epi32(wb, xv));
                            }
                            wo += l;
                        }
                    }
                    let mut tile = [0i32; 8];
                    for (r, a) in acc.iter().enumerate() {
                        let ri = base + r;
                        let fr = self.row_scale[ri];
                        if fr == 0.0 {
                            continue;
                        }
                        _mm256_storeu_si256(tile.as_mut_ptr() as *mut __m256i, *a);
                        let drow = ctx.rows[ri] as usize * bcols + t0;
                        for (j, &v) in tile.iter().enumerate() {
                            buf[drow + j] += v as f64 * fr;
                        }
                    }
                    t0 += 8;
                }
            }
            if t8 < bcols {
                self.panel_rows_scalar(ctx, buf, pi, t8, bcols);
            }
        }
        self.tail_rows_scalar(ctx, buf, 0, bcols);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShiftRng;

    /// The scalar oracle: one row at a time, zero-skipping — the exact
    /// pre-PR4 `ChunkPlan::accumulate` inner sweep.
    fn naive(
        w: &[f64],
        ncols: usize,
        xq: &[f64],
        bcols: usize,
        buf: &mut [f64],
        rows: &[u32],
    ) {
        for (ri, &row) in rows.iter().enumerate() {
            let dst = &mut buf[row as usize * bcols..row as usize * bcols + bcols];
            let wrow = &w[ri * ncols..(ri + 1) * ncols];
            for (ci, &wv) in wrow.iter().enumerate() {
                if wv == 0.0 {
                    continue;
                }
                let xrow = &xq[ci * bcols..(ci + 1) * bcols];
                for (d, &xv) in dst.iter_mut().zip(xrow) {
                    *d += wv * xv;
                }
            }
        }
    }

    fn random_panel(
        nrows: usize,
        ncols: usize,
        zero_frac: f64,
        rng: &mut XorShiftRng,
    ) -> Vec<f64> {
        (0..nrows * ncols)
            .map(|_| {
                if rng.uniform() < zero_frac {
                    0.0
                } else {
                    rng.uniform() * 2.0 - 1.0
                }
            })
            .collect()
    }

    #[test]
    fn packed_kernel_matches_scalar_sweep() {
        let mut rng = XorShiftRng::new(42);
        for &(nrows, ncols) in
            &[(0, 5), (1, 7), (3, 4), (4, 9), (5, 1), (8, 16), (11, 13), (16, 64)]
        {
            for &bcols in &[1usize, 2, 5, 8] {
                for &zero_frac in &[0.0, 0.3, 0.9] {
                    let w = random_panel(nrows, ncols, zero_frac, &mut rng);
                    // sparse ascending destination-row table with gaps
                    let rows: Vec<u32> = (0..nrows as u32).map(|i| i * 2 + 1).collect();
                    let buf_rows = nrows * 2 + 2;
                    let mut xq = vec![0.0; ncols * bcols];
                    rng.fill_uniform(&mut xq, 0.0, 1.0);

                    let mut want = vec![0.0; buf_rows * bcols];
                    naive(&w, ncols, &xq, bcols, &mut want, &rows);

                    let panel = PackedPanel::pack(&w, nrows, ncols);
                    assert_eq!(panel.dims(), (nrows, ncols));
                    let mut got = vec![0.0; buf_rows * bcols];
                    panel.accumulate(&xq, bcols, &mut got, &rows);

                    for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
                        assert!(
                            (g - w_).abs() < 1e-12,
                            "{nrows}x{ncols} b={bcols} z={zero_frac} idx {i}: {g} vs {w_}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn zero_column_spans_are_compiled_out() {
        // 4 rows × 16 cols with columns 4..12 all-zero: one quad, two
        // runs, and the packed column count excludes the dead span
        let mut w = vec![1.0; 4 * 16];
        for row in 0..4 {
            for ci in 4..12 {
                w[row * 16 + ci] = 0.0;
            }
        }
        let panel = PackedPanel::pack(&w, 4, 16);
        assert_eq!(panel.quads.len(), 1);
        assert_eq!(panel.quads[0].1, 2, "two runs around the zero span");
        assert_eq!(panel.packed_cols(), 8, "8 of 16 columns survive packing");
    }

    #[test]
    fn tail_rows_are_run_compressed() {
        // nrows in {1, 2, 3, 5, 7}: every shape with a non-multiple-of-4
        // tail. Columns 4..12 of 16 are zero in every row, so each tail
        // row must pack 8 columns as two runs — not 16 dense ones.
        for &nrows in &[1usize, 2, 3, 5, 7] {
            let ncols = 16;
            let mut w = vec![1.0; nrows * ncols];
            for row in 0..nrows {
                for ci in 4..12 {
                    w[row * ncols + ci] = 0.0;
                }
            }
            let panel = PackedPanel::pack(&w, nrows, ncols);
            let tail = nrows % 4;
            assert_eq!(panel.tail_rows.len(), tail, "nrows={nrows}");
            assert_eq!(
                panel.packed_cols(),
                8 * (nrows / 4) + 8 * tail,
                "nrows={nrows}: dead span must be compiled out of the tail"
            );
            for &(_, nruns) in &panel.tail_rows {
                assert_eq!(nruns, 2, "nrows={nrows}: two runs around the zero span");
            }
            // and the packed result still matches the scalar oracle
            let rows: Vec<u32> = (0..nrows as u32).collect();
            let bcols = 3;
            let xq: Vec<f64> = (0..ncols * bcols).map(|i| i as f64 * 0.01).collect();
            let mut want = vec![0.0; nrows * bcols];
            naive(&w, ncols, &xq, bcols, &mut want, &rows);
            let mut got = vec![0.0; nrows * bcols];
            panel.accumulate(&xq, bcols, &mut got, &rows);
            assert_eq!(got, want, "nrows={nrows}");
        }
    }

    #[test]
    fn all_zero_panel_has_no_runs() {
        // 8×6 (quads only) and 7×6 (tail rows too): nothing packed
        for &nrows in &[8usize, 7] {
            let w = vec![0.0; nrows * 6];
            let panel = PackedPanel::pack(&w, nrows, 6);
            assert_eq!(panel.packed_cols(), 0);
            let xq = vec![1.0; 6 * 3];
            let rows: Vec<u32> = (0..nrows as u32).collect();
            let mut buf = vec![0.0; nrows * 3];
            panel.accumulate(&xq, 3, &mut buf, &rows);
            assert!(buf.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn empty_panel_is_a_noop() {
        let panel = PackedPanel::pack(&[], 0, 0);
        assert_eq!(panel.dims(), (0, 0));
        let mut buf: Vec<f64> = Vec::new();
        panel.accumulate(&[], 1, &mut buf, &[]);
    }

    #[test]
    fn precision_parses_and_round_trips() {
        assert_eq!("exact".parse::<KernelPrecision>(), Ok(KernelPrecision::Exact));
        assert_eq!(
            "Quantized".parse::<KernelPrecision>(),
            Ok(KernelPrecision::Quantized)
        );
        assert!("fp8".parse::<KernelPrecision>().is_err());
        assert_eq!(KernelPrecision::default(), KernelPrecision::Exact);
        for p in [KernelPrecision::Exact, KernelPrecision::Quantized] {
            assert_eq!(p.as_str().parse::<KernelPrecision>(), Ok(p));
        }
    }

    #[test]
    fn simd_resolution_policy() {
        let none = CpuFeatures::default();
        let avx2 = CpuFeatures { avx2: true, fma: true, ..none };
        let avx512 = CpuFeatures { avx512f: true, ..avx2 };
        assert_eq!(resolve_simd(false, none), SimdLevel::Scalar);
        assert_eq!(resolve_simd(false, avx2), SimdLevel::Avx2);
        assert_eq!(resolve_simd(false, avx512), SimdLevel::Avx512);
        // the override always wins
        assert_eq!(resolve_simd(true, avx512), SimdLevel::Scalar);
        // avx512f without avx2 (not a real CPU) still falls back
        let weird = CpuFeatures { avx512f: true, ..none };
        assert_eq!(resolve_simd(false, weird), SimdLevel::Scalar);
        // lane widths
        assert_eq!(SimdLevel::Scalar.lanes(), 8);
        assert_eq!(SimdLevel::Avx2.lanes(), 8);
        assert_eq!(SimdLevel::Avx512.lanes(), 16);
    }

    #[test]
    fn force_scalar_env_values() {
        assert!(env_forces_scalar(Some("1")));
        assert!(env_forces_scalar(Some("true")));
        assert!(env_forces_scalar(Some("TRUE")));
        assert!(!env_forces_scalar(Some("0")));
        assert!(!env_forces_scalar(Some("")));
        assert!(!env_forces_scalar(None));
    }

    /// Test-side integer reference: exact i32 dot products from the
    /// quantized codes, one f64 fold per output — the contract both
    /// sweeps must match bit-for-bit.
    fn naive_quant(
        panel: &QuantPanel,
        w: &[f64],
        ncols: usize,
        xq: &[i16],
        bcols: usize,
        buf: &mut [f64],
        rows: &[u32],
    ) {
        for (ri, &row) in rows.iter().enumerate() {
            let fr = panel.row_scale[ri];
            if fr == 0.0 {
                continue;
            }
            let wrow = &w[ri * ncols..(ri + 1) * ncols];
            let wmax = wrow.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
            let sw = wmax / W_LEVELS;
            for t in 0..bcols {
                let mut acc: i32 = 0;
                for (ci, &wv) in wrow.iter().enumerate() {
                    let code = (wv / sw).round() as i32;
                    acc += code * xq[ci * bcols + t] as i32;
                }
                buf[row as usize * bcols + t] += acc as f64 * fr;
            }
        }
    }

    fn random_codes(n: usize, rng: &mut XorShiftRng) -> Vec<i16> {
        (0..n).map(|_| (rng.uniform() * ACT_LEVELS).round() as i16).collect()
    }

    #[test]
    fn quant_scalar_matches_integer_reference() {
        let mut rng = XorShiftRng::new(7);
        for &lanes in &[8usize, 16] {
            for &(nrows, ncols) in
                &[(1, 7), (5, 3), (8, 16), (9, 5), (16, 11), (17, 64), (33, 9)]
            {
                for &bcols in &[1usize, 3, 8, 17, 64] {
                    let w = random_panel(nrows, ncols, 0.4, &mut rng);
                    let rows: Vec<u32> = (0..nrows as u32).map(|i| i * 2).collect();
                    let buf_rows = nrows * 2 + 1;
                    let xq = random_codes(ncols * bcols, &mut rng);
                    let panel = QuantPanel::pack(&w, nrows, ncols, lanes);
                    assert_eq!(panel.dims(), (nrows, ncols));
                    assert_eq!(panel.lane_width(), lanes);

                    let mut want = vec![0.0; buf_rows * bcols];
                    naive_quant(&panel, &w, ncols, &xq, bcols, &mut want, &rows);
                    let mut got = vec![0.0; buf_rows * bcols];
                    panel.accumulate(&xq, bcols, &mut got, &rows, SimdLevel::Scalar);
                    assert_eq!(
                        got, want,
                        "lanes={lanes} {nrows}x{ncols} b={bcols}: scalar sweep \
                         must equal the integer reference bit-for-bit"
                    );
                }
            }
        }
    }

    #[test]
    fn quant_tracks_f64_panel_within_quantization_error() {
        let mut rng = XorShiftRng::new(11);
        let (nrows, ncols, bcols) = (16, 24, 8);
        let w = random_panel(nrows, ncols, 0.3, &mut rng);
        let rows: Vec<u32> = (0..nrows as u32).collect();
        // activations on the code grid so only weight quantization and
        // fold rounding separate the two paths
        let xq = random_codes(ncols * bcols, &mut rng);
        let xf: Vec<f64> = xq.iter().map(|&c| c as f64 / ACT_LEVELS).collect();

        let mut want = vec![0.0; nrows * bcols];
        naive(&w, ncols, &xf, bcols, &mut want, &rows);
        let panel = QuantPanel::pack(&w, nrows, ncols, 8);
        let mut got = vec![0.0; nrows * bcols];
        panel.accumulate(&xq, bcols, &mut got, &rows, SimdLevel::Scalar);

        // per-term weight error <= sw/2 = wmax/254, |x| <= 1
        let tol = ncols as f64 * (1.0 / 254.0) * 1.05 + 1e-9;
        for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w_).abs() <= tol,
                "idx {i}: quantized {g} vs f64 {w_} (tol {tol})"
            );
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn quant_simd_equals_scalar_bit_for_bit() {
        if !cpu_features().avx2 {
            eprintln!("skipping: no AVX2 on this host");
            return;
        }
        let mut rng = XorShiftRng::new(23);
        for &lanes in &[8usize, 16] {
            let level = if lanes == 16 { SimdLevel::Avx512 } else { SimdLevel::Avx2 };
            for &(nrows, ncols) in
                &[(1, 5), (7, 16), (8, 16), (15, 33), (16, 64), (31, 13), (48, 64)]
            {
                for &bcols in &[1usize, 7, 8, 9, 17, 64] {
                    for &zero_frac in &[0.0, 0.5, 0.95] {
                        let w = random_panel(nrows, ncols, zero_frac, &mut rng);
                        let rows: Vec<u32> =
                            (0..nrows as u32).map(|i| i * 2 + 1).collect();
                        let buf_rows = nrows * 2 + 2;
                        let xq = random_codes(ncols * bcols, &mut rng);
                        let panel = QuantPanel::pack(&w, nrows, ncols, lanes);

                        // bias pre-seeded so the fold order interacts
                        // with nonzero destinations
                        let mut scalar = vec![0.25; buf_rows * bcols];
                        let mut simd = scalar.clone();
                        panel.accumulate(
                            &xq,
                            bcols,
                            &mut scalar,
                            &rows,
                            SimdLevel::Scalar,
                        );
                        panel.accumulate(&xq, bcols, &mut simd, &rows, level);
                        assert_eq!(
                            simd, scalar,
                            "lanes={lanes} {nrows}x{ncols} b={bcols} z={zero_frac}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn quant_all_zero_and_empty_panels_are_noops() {
        let w = vec![0.0; 9 * 6];
        let panel = QuantPanel::pack(&w, 9, 6, 8);
        assert_eq!(panel.packed_cols(), 0);
        let xq = vec![1023i16; 6 * 3];
        let rows: Vec<u32> = (0..9).collect();
        let mut buf = vec![0.0; 9 * 3];
        panel.accumulate(&xq, 3, &mut buf, &rows, SimdLevel::Scalar);
        assert!(buf.iter().all(|&v| v == 0.0));

        let empty = QuantPanel::pack(&[], 0, 0, 8);
        assert_eq!(empty.dims(), (0, 0));
        let mut buf: Vec<f64> = Vec::new();
        empty.accumulate(&[], 1, &mut buf, &[], SimdLevel::Scalar);

        let default = QuantPanel::default();
        let mut buf: Vec<f64> = Vec::new();
        default.accumulate(&[], 1, &mut buf, &[], SimdLevel::Scalar);
    }

    #[test]
    fn quant_zero_spans_and_quantized_to_zero_weights_are_compiled_out() {
        // 8×16, columns 4..12 zero; column 0 is tiny enough to quantize
        // to code 0 in every row (wmax = 1.0 -> sw = 1/127; |w| < sw/2)
        let mut w = vec![1.0; 8 * 16];
        for row in 0..8 {
            for ci in 4..12 {
                w[row * 16 + ci] = 0.0;
            }
            w[row * 16] = 1.0e-4;
        }
        let panel = QuantPanel::pack(&w, 8, 16, 8);
        assert_eq!(panel.panels.len(), 1);
        assert_eq!(
            panel.packed_cols(),
            7,
            "cols 1..4 and 12..16 survive; col 0 quantizes to zero"
        );
    }
}

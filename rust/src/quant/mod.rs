//! Quantization helpers (§4.1: b_w = 8-bit symmetric signed per-tensor
//! weights, b_in = 6-bit activations) and the non-negative isomorphic input
//! transform of §3.3.1 (inputs must ride on light intensity, which is
//! positive-only).


/// Symmetric signed per-tensor quantizer: x → round(x/Δ)·Δ with
/// Δ = max|x| / (2^(b−1) − 1).
#[derive(Debug, Clone, Copy)]
pub struct SymmetricQuant {
    pub bits: u8,
    pub scale: f64,
}

impl SymmetricQuant {
    /// Calibrate the scale from data.
    pub fn calibrate(bits: u8, data: &[f64]) -> Self {
        assert!(bits >= 2);
        let max = data.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
        let levels = ((1u64 << (bits - 1)) - 1) as f64;
        Self { bits, scale: if max == 0.0 { 1.0 } else { max / levels } }
    }

    pub fn with_scale(bits: u8, scale: f64) -> Self {
        Self { bits, scale }
    }

    #[inline]
    pub fn quantize(&self, x: f64) -> f64 {
        let levels = ((1u64 << (self.bits - 1)) - 1) as f64;
        (x / self.scale).round().clamp(-levels, levels) * self.scale
    }

    pub fn quantize_slice(&self, xs: &mut [f64]) {
        for x in xs.iter_mut() {
            *x = self.quantize(*x);
        }
    }

    /// Integer code for x.
    pub fn code(&self, x: f64) -> i64 {
        let levels = ((1u64 << (self.bits - 1)) - 1) as i64;
        ((x / self.scale).round() as i64).clamp(-levels, levels)
    }
}

/// Unsigned activation quantizer over [0, max]: the paper's 6-bit
/// activations after the non-negative transform.
#[derive(Debug, Clone, Copy)]
pub struct UnsignedQuant {
    pub bits: u8,
    pub max: f64,
}

impl UnsignedQuant {
    pub fn calibrate(bits: u8, data: &[f64]) -> Self {
        let max = data.iter().fold(0.0f64, |m, &x| m.max(x));
        Self { bits, max: if max == 0.0 { 1.0 } else { max } }
    }

    #[inline]
    pub fn quantize(&self, x: f64) -> f64 {
        let levels = ((1u64 << self.bits) - 1) as f64;
        (x.clamp(0.0, self.max) / self.max * levels).round() / levels * self.max
    }
}

/// Non-negative isomorphic transform (§3.3.1, [13]): shift a signed input
/// vector to x′ = x + b with b = −min(x, 0) so the optical intensity is
/// positive; the output is corrected by subtracting W·b (accumulated once
/// per weight row as a digital bias).
#[derive(Debug, Clone)]
pub struct NonNegTransform {
    pub bias: f64,
}

impl NonNegTransform {
    pub fn from_data(x: &[f64]) -> Self {
        let min = x.iter().fold(0.0f64, |m, &v| m.min(v));
        Self { bias: -min }
    }

    /// Shifted, guaranteed non-negative input.
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        x.iter().map(|&v| v + self.bias).collect()
    }

    /// Correction to subtract from output i: bias · Σ_j w_ij.
    pub fn output_correction(&self, weight_row_sum: f64) -> f64 {
        self.bias * weight_row_sum
    }
}

/// Normalize a weight matrix to the PTC's implementable range [−1, 1]
/// (§3.3.1); returns the scale to re-apply at readout.
pub fn normalize_weights(w: &mut [f64]) -> f64 {
    let max = w.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
    if max == 0.0 {
        return 1.0;
    }
    for x in w.iter_mut() {
        *x /= max;
    }
    max
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_roundtrip_error_bounded() {
        let data: Vec<f64> = (0..100).map(|i| (i as f64 - 50.0) / 37.0).collect();
        let q = SymmetricQuant::calibrate(8, &data);
        for &x in &data {
            assert!((q.quantize(x) - x).abs() <= q.scale / 2.0 + 1e-12);
        }
    }

    #[test]
    fn symmetric_preserves_zero_and_sign() {
        let q = SymmetricQuant::with_scale(8, 0.01);
        assert_eq!(q.quantize(0.0), 0.0);
        assert!(q.quantize(0.5) > 0.0);
        assert!(q.quantize(-0.5) < 0.0);
        assert_eq!(q.quantize(0.5), -q.quantize(-0.5));
    }

    #[test]
    fn code_range_8bit() {
        let q = SymmetricQuant::with_scale(8, 1.0 / 127.0);
        assert_eq!(q.code(1.0), 127);
        assert_eq!(q.code(-1.0), -127);
        assert_eq!(q.code(10.0), 127, "clamped");
    }

    #[test]
    fn unsigned_levels_6bit() {
        let q = UnsignedQuant { bits: 6, max: 1.0 };
        let lsb = 1.0 / 63.0;
        assert!((q.quantize(0.5) - 0.5).abs() <= lsb / 2.0 + 1e-12);
        assert_eq!(q.quantize(-1.0), 0.0);
        assert_eq!(q.quantize(2.0), 1.0);
    }

    #[test]
    fn nonneg_transform_correctness() {
        let x = vec![-0.5, 0.25, -1.0, 0.75];
        let w = vec![0.3, -0.2, 0.9, 0.1];
        let t = NonNegTransform::from_data(&x);
        let xs = t.apply(&x);
        assert!(xs.iter().all(|&v| v >= 0.0));
        // y' - correction == y
        let y: f64 = w.iter().zip(&x).map(|(a, b)| a * b).sum();
        let y_shift: f64 = w.iter().zip(&xs).map(|(a, b)| a * b).sum();
        let corrected = y_shift - t.output_correction(w.iter().sum());
        assert!((corrected - y).abs() < 1e-12);
    }

    #[test]
    fn normalize_weights_unit_range() {
        let mut w = vec![0.5, -2.0, 1.0];
        let s = normalize_weights(&mut w);
        assert_eq!(s, 2.0);
        assert_eq!(w, vec![0.25, -1.0, 0.5]);
        let mut z = vec![0.0, 0.0];
        assert_eq!(normalize_weights(&mut z), 1.0);
    }
}

//! Named configuration presets for the Fig.-10 progressive optimization
//! waterfall (§4.2.4). Each step changes exactly one design axis relative
//! to the previous step, so the harness can attribute power/area deltas.

use super::{AcceleratorConfig, DacKind, SparsitySupport};

/// One step of the Fig.-10 waterfall: a label, the config, and the model
/// sparsity deployed on it (1.0 = dense).
#[derive(Debug, Clone)]
pub struct Fig10Step {
    pub label: &'static str,
    pub description: &'static str,
    pub config: AcceleratorConfig,
    /// Fraction of nonzero weights (paper's `s`; 1.0 = dense).
    pub density: f64,
    /// Whether the deployed masks are power-optimized (step 5+).
    pub power_opt_masks: bool,
}

/// The seven progressive steps of Fig. 10 plus the step-0 baseline.
pub fn fig10_steps() -> Vec<Fig10Step> {
    let base = AcceleratorConfig::foundry_baseline();

    // Step 1: swap Foundry-MZI -> LP-MZI, keeping conservative spacing
    // (l_s = 15 um: negligible intra-MZI coupling; l_g = 20 um).
    let mut s1 = AcceleratorConfig::foundry_baseline();
    s1.mzi = super::MziKind::LowPower;
    s1.l_s = 15.0;
    s1.l_v = 120.0;

    // Step 2: optimal dense device spacing l_s = 9 (small intra-MZI power
    // penalty, Fig. 4(c)), l_g = 5 (23% area saving).
    let mut s2 = s1.clone();
    s2.l_s = 9.0;
    s2.l_g = 5.0;

    // Step 3: architectural sharing r = c = 4.
    let mut s3 = s2.clone();
    s3.share_r = 4;
    s3.share_c = 4;

    // Step 4: s = 0.3 row-column co-sparsity + output gating lets
    // l_g shrink to 1 µm.
    let mut s4 = s3.clone();
    s4.l_g = 1.0;
    s4.features = SparsitySupport { input_gating: false, output_gating: true, ..SparsitySupport::NONE };

    // Step 5: power-aware pruning/growth (power-optimized column masks).
    let s5 = s4.clone();

    // Step 6: input/output gating + light redistribution.
    let mut s6 = s5.clone();
    s6.features = SparsitySupport::FULL;

    // Step 7: hybrid eoDAC (2 x 3-bit, two-segment MZM).
    let mut s7 = s6.clone();
    s7.dac = DacKind::optimal_eodac();

    vec![
        Fig10Step {
            label: "0:baseline",
            description: "dense, Foundry-MZI, l_g=20um, dedicated converters (r=c=1)",
            config: base,
            density: 1.0,
            power_opt_masks: false,
        },
        Fig10Step {
            label: "1:LP-MZI",
            description: "swap foundry MZI for compact low-power LP-MZI",
            config: s1,
            density: 1.0,
            power_opt_masks: false,
        },
        Fig10Step {
            label: "2:spacing",
            description: "optimal dense spacing l_s=9um, l_g=5um",
            config: s2,
            density: 1.0,
            power_opt_masks: false,
        },
        Fig10Step {
            label: "3:sharing",
            description: "share input modulation and readout, r=c=4",
            config: s3,
            density: 1.0,
            power_opt_masks: false,
        },
        Fig10Step {
            label: "4:sparsity",
            description: "s=0.3 row-column co-sparsity + OG, shrink l_g to 1um",
            config: s4,
            density: 0.3,
            power_opt_masks: false,
        },
        Fig10Step {
            label: "5:power-opt",
            description: "power-aware pruning/growth selects low-power column masks",
            config: s5,
            density: 0.3,
            power_opt_masks: true,
        },
        Fig10Step {
            label: "6:IG+OG+LR",
            description: "input/output gating + in-situ light redistribution",
            config: s6,
            density: 0.3,
            power_opt_masks: true,
        },
        Fig10Step {
            label: "7:eoDAC",
            description: "hybrid 2x3-bit eoDAC replaces 6-bit eDAC",
            config: s7,
            density: 0.3,
            power_opt_masks: true,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_steps_all_valid() {
        let steps = fig10_steps();
        assert_eq!(steps.len(), 8);
        for s in &steps {
            s.config.validate().unwrap_or_else(|e| panic!("{}: {e}", s.label));
        }
    }

    #[test]
    fn steps_change_one_axis_at_a_time() {
        let steps = fig10_steps();
        // step1 changes device only
        assert_eq!(steps[1].config.l_g, steps[0].config.l_g);
        assert_ne!(steps[1].config.mzi, steps[0].config.mzi);
        // step2 changes l_g only
        assert_eq!(steps[2].config.mzi, steps[1].config.mzi);
        assert!(steps[2].config.l_g < steps[1].config.l_g);
        // step3 changes sharing
        assert_eq!(steps[3].config.share_r, 4);
        // step4 enables sparsity + shrinks l_g
        assert!(steps[4].density < 1.0);
        assert_eq!(steps[4].config.l_g, 1.0);
        // step7 swaps the DAC
        assert_eq!(steps[7].config.dac, DacKind::optimal_eodac());
    }
}

//! Accelerator configuration: architecture dims, device spacings, device
//! library selection, converter resolutions, and clock.
//!
//! All of the paper's design-space axes (Table 1, Table 2, Figs. 6, 8, 10)
//! are fields here, and the progressive Fig.-10 optimization steps are
//! provided as named presets.

mod presets;

pub use presets::{fig10_steps, Fig10Step};

use crate::Error;

/// Which MZI power-splitter device the weight array uses (§3.3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MziKind {
    /// Foundry-provided switch: Pπ = 30 mW, 550 µm × 156.25 µm.
    Foundry,
    /// The paper's optimized low-power MZI: Pπ = 15.02 mW, 115 µm × (l_s + 6) µm.
    LowPower,
}

/// Input-modulation DAC style (§3.3.4, Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DacKind {
    /// Single full-resolution electronic DAC.
    Edac,
    /// Hybrid electronic-optic DAC: `segments` sub-DACs of `bits_per_seg`
    /// bits each driving non-uniform MZM segments (optimal: 2 × 3-bit, 8:1).
    Eodac { segments: u8, bits_per_seg: u8 },
}

impl DacKind {
    /// The paper's optimal eoDAC: two 3-bit eDACs + two-segment MZM (8:1).
    pub fn optimal_eodac() -> Self {
        DacKind::Eodac { segments: 2, bits_per_seg: 3 }
    }
}

/// Gating / light-redistribution feature flags (§3.3.2, §3.3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparsitySupport {
    /// Input gating: power-gate DACs/MZMs on pruned columns.
    pub input_gating: bool,
    /// Output gating: power-gate TIA/ADC on pruned rows.
    pub output_gating: bool,
    /// In-situ light redistribution via the tunable rerouter.
    pub light_redistribution: bool,
}

impl SparsitySupport {
    pub const NONE: Self =
        Self { input_gating: false, output_gating: false, light_redistribution: false };
    pub const IG: Self =
        Self { input_gating: true, output_gating: false, light_redistribution: false };
    pub const IG_OG: Self =
        Self { input_gating: true, output_gating: true, light_redistribution: false };
    /// Full SCATTER: IG + OG + LR.
    pub const FULL: Self =
        Self { input_gating: true, output_gating: true, light_redistribution: true };
}

/// Full accelerator configuration. Field names follow the paper's symbols.
#[derive(Debug, Clone)]
pub struct AcceleratorConfig {
    /// Number of tiles (paper: R = 4).
    pub tiles_r: usize,
    /// PTCs per tile (paper: C = 4).
    pub cores_c: usize,
    /// PTC columns = output dim per core (paper: k1 = 16).
    pub k1: usize,
    /// PTC rows = input dim per core (paper: k2 = 16).
    pub k2: usize,
    /// Input-modulation sharing factor across tiles (paper: r).
    pub share_r: usize,
    /// Readout sharing factor within a tile (paper: c).
    pub share_c: usize,
    /// Clock frequency in GHz (paper: f = 5).
    pub freq_ghz: f64,
    /// Input/activation DAC resolution in bits (paper: b_in = 6).
    pub b_in: u8,
    /// Weight DAC resolution in bits (paper: b_w = 8; low-speed, off-chip).
    pub b_w: u8,
    /// Readout ADC resolution in bits (paper: b_o = 8).
    pub b_o: u8,
    /// MZI arm (phase-shifter) spacing l_s in µm (optimal: 9).
    pub l_s: f64,
    /// Horizontal gap between adjacent MZIs l_g in µm (dense-optimal: 5).
    pub l_g: f64,
    /// Vertical MZI pitch l_v in µm (layout constant: 120 for LP-MZI).
    pub l_v: f64,
    /// Weight-array MZI device.
    pub mzi: MziKind,
    /// Input DAC architecture.
    pub dac: DacKind,
    /// Gating/LR features enabled on this build.
    pub features: SparsitySupport,
    /// RNG seed for hardware noise draws.
    pub noise_seed: u64,
}

impl Default for AcceleratorConfig {
    /// The paper's final SCATTER configuration (§4.1 + Fig. 10 step 7):
    /// R=C=4, k1=k2=16, r=c=4, 5 GHz, LP-MZI at l_s=9/l_g=1, eoDAC, full
    /// gating + light redistribution.
    fn default() -> Self {
        Self {
            tiles_r: 4,
            cores_c: 4,
            k1: 16,
            k2: 16,
            share_r: 4,
            share_c: 4,
            freq_ghz: 5.0,
            b_in: 6,
            b_w: 8,
            b_o: 8,
            l_s: 9.0,
            l_g: 1.0,
            l_v: 120.0,
            mzi: MziKind::LowPower,
            dac: DacKind::optimal_eodac(),
            features: SparsitySupport::FULL,
            noise_seed: 0x5CA77E2,
        }
    }
}

impl AcceleratorConfig {
    /// The dense baseline of Table 1 / Fig. 10 step ③: LP-MZI, optimal
    /// dense spacing (l_s=9, l_g=5), shared converters, no sparsity HW.
    pub fn dense_optimal() -> Self {
        Self {
            l_g: 5.0,
            dac: DacKind::Edac,
            features: SparsitySupport::NONE,
            ..Self::default()
        }
    }

    /// The conservative foundry dense baseline of Fig. 10 step ⓪:
    /// Foundry-MZI, l_g = 20 µm, dedicated converters (r = c = 1).
    pub fn foundry_baseline() -> Self {
        Self {
            share_r: 1,
            share_c: 1,
            l_s: 50.0,
            l_g: 20.0,
            l_v: 570.0,
            mzi: MziKind::Foundry,
            dac: DacKind::Edac,
            features: SparsitySupport::NONE,
            ..Self::default()
        }
    }

    /// Horizontal MZI pitch l_h = l_g + node width (µm). Eq. 6 uses
    /// `(k1-1)·l_h + l_s + w_PS`, i.e. pitch = gap + device width.
    pub fn l_h(&self) -> f64 {
        self.l_g + self.node_width()
    }

    /// Physical node (MZI) width in µm: l_s + w_PS for the LP device,
    /// the fixed foundry width otherwise.
    pub fn node_width(&self) -> f64 {
        match self.mzi {
            MziKind::LowPower => self.l_s + crate::devices::mzi::LP_PS_WIDTH_UM,
            MziKind::Foundry => crate::devices::mzi::FOUNDRY_WIDTH_UM,
        }
    }

    /// Physical node length (along light propagation) in µm:
    /// l_Y + l_PS + l_DC = 115 for the LP device; 550 for foundry.
    pub fn node_length(&self) -> f64 {
        match self.mzi {
            MziKind::LowPower => crate::devices::mzi::LP_LENGTH_UM,
            MziKind::Foundry => crate::devices::mzi::FOUNDRY_LENGTH_UM,
        }
    }

    /// Total number of PTCs.
    pub fn n_cores(&self) -> usize {
        self.tiles_r * self.cores_c
    }

    /// Weight-chunk shape handled per cycle: rows = r·k1, cols = c·k2
    /// (§3.3.5: pruning granularity is length-r·k1 columns / length-c·k2 rows).
    pub fn chunk_shape(&self) -> (usize, usize) {
        (self.share_r * self.k1, self.share_c * self.k2)
    }

    pub fn validate(&self) -> crate::Result<()> {
        if self.tiles_r == 0 || self.cores_c == 0 || self.k1 == 0 || self.k2 == 0 {
            return Err(Error::Config("architecture dims must be nonzero".into()));
        }
        if self.share_r == 0 || self.share_r > self.tiles_r {
            return Err(Error::Config(format!(
                "input sharing factor r={} must be in 1..=R={}",
                self.share_r, self.tiles_r
            )));
        }
        if self.share_c == 0 || self.share_c > self.cores_c {
            return Err(Error::Config(format!(
                "readout sharing factor c={} must be in 1..=C={}",
                self.share_c, self.cores_c
            )));
        }
        if self.l_s <= 0.0 || self.l_g < 0.0 || self.l_v <= 0.0 {
            return Err(Error::Config("spacings must be positive".into()));
        }
        if self.freq_ghz <= 0.0 {
            return Err(Error::Config("clock frequency must be positive".into()));
        }
        if self.b_in == 0 || self.b_w == 0 || self.b_o == 0 {
            return Err(Error::Config("bit widths must be nonzero".into()));
        }
        if let DacKind::Eodac { segments, bits_per_seg } = self.dac {
            if segments == 0 || bits_per_seg == 0 {
                return Err(Error::Config("eoDAC segments/bits must be nonzero".into()));
            }
            if segments as u32 * bits_per_seg as u32 != self.b_in as u32 {
                return Err(Error::Config(format!(
                    "eoDAC segments({segments}) x bits({bits_per_seg}) must equal b_in({})",
                    self.b_in
                )));
            }
        }
        if self.features.light_redistribution && !self.features.input_gating {
            return Err(Error::Config(
                "light redistribution requires input gating (rerouter steals gated ports)".into(),
            ));
        }
        Ok(())
    }

    /// Serialize to JSON (hand-rolled; the offline build has no serde).
    pub fn to_json(&self) -> String {
        use crate::util::Json;
        let dac = match self.dac {
            DacKind::Edac => Json::obj(vec![("kind", Json::Str("edac".into()))]),
            DacKind::Eodac { segments, bits_per_seg } => Json::obj(vec![
                ("kind", Json::Str("eodac".into())),
                ("segments", Json::Num(segments as f64)),
                ("bits_per_seg", Json::Num(bits_per_seg as f64)),
            ]),
        };
        Json::obj(vec![
            ("tiles_r", Json::Num(self.tiles_r as f64)),
            ("cores_c", Json::Num(self.cores_c as f64)),
            ("k1", Json::Num(self.k1 as f64)),
            ("k2", Json::Num(self.k2 as f64)),
            ("share_r", Json::Num(self.share_r as f64)),
            ("share_c", Json::Num(self.share_c as f64)),
            ("freq_ghz", Json::Num(self.freq_ghz)),
            ("b_in", Json::Num(self.b_in as f64)),
            ("b_w", Json::Num(self.b_w as f64)),
            ("b_o", Json::Num(self.b_o as f64)),
            ("l_s", Json::Num(self.l_s)),
            ("l_g", Json::Num(self.l_g)),
            ("l_v", Json::Num(self.l_v)),
            (
                "mzi",
                Json::Str(
                    match self.mzi {
                        MziKind::Foundry => "foundry",
                        MziKind::LowPower => "low_power",
                    }
                    .into(),
                ),
            ),
            ("dac", dac),
            ("input_gating", Json::Bool(self.features.input_gating)),
            ("output_gating", Json::Bool(self.features.output_gating)),
            ("light_redistribution", Json::Bool(self.features.light_redistribution)),
            ("noise_seed", Json::Num(self.noise_seed as f64)),
        ])
        .to_string()
    }

    pub fn from_json(s: &str) -> crate::Result<Self> {
        use crate::util::Json;
        let v = Json::parse(s).map_err(Error::Serde)?;
        let num = |k: &str, d: f64| v.get(k).and_then(Json::as_f64).unwrap_or(d);
        let def = Self::default();
        let dac = match v.get("dac") {
            Some(d) => match d.get("kind").and_then(Json::as_str) {
                Some("edac") => DacKind::Edac,
                Some("eodac") => DacKind::Eodac {
                    segments: d.get("segments").and_then(Json::as_f64).unwrap_or(2.0) as u8,
                    bits_per_seg: d.get("bits_per_seg").and_then(Json::as_f64).unwrap_or(3.0)
                        as u8,
                },
                _ => return Err(Error::Serde("unknown dac kind".into())),
            },
            None => def.dac,
        };
        let mzi = match v.get("mzi").and_then(Json::as_str) {
            Some("foundry") => MziKind::Foundry,
            Some("low_power") | None => MziKind::LowPower,
            Some(other) => return Err(Error::Serde(format!("unknown mzi kind '{other}'"))),
        };
        let flag = |k: &str, d: bool| v.get(k).and_then(Json::as_bool).unwrap_or(d);
        let cfg = Self {
            tiles_r: num("tiles_r", def.tiles_r as f64) as usize,
            cores_c: num("cores_c", def.cores_c as f64) as usize,
            k1: num("k1", def.k1 as f64) as usize,
            k2: num("k2", def.k2 as f64) as usize,
            share_r: num("share_r", def.share_r as f64) as usize,
            share_c: num("share_c", def.share_c as f64) as usize,
            freq_ghz: num("freq_ghz", def.freq_ghz),
            b_in: num("b_in", def.b_in as f64) as u8,
            b_w: num("b_w", def.b_w as f64) as u8,
            b_o: num("b_o", def.b_o as f64) as u8,
            l_s: num("l_s", def.l_s),
            l_g: num("l_g", def.l_g),
            l_v: num("l_v", def.l_v),
            mzi,
            dac,
            features: SparsitySupport {
                input_gating: flag("input_gating", def.features.input_gating),
                output_gating: flag("output_gating", def.features.output_gating),
                light_redistribution: flag(
                    "light_redistribution",
                    def.features.light_redistribution,
                ),
            },
            noise_seed: num("noise_seed", def.noise_seed as f64) as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> crate::Result<Self> {
        Self::from_json(&std::fs::read_to_string(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        AcceleratorConfig::default().validate().unwrap();
        AcceleratorConfig::dense_optimal().validate().unwrap();
        AcceleratorConfig::foundry_baseline().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        for cfg in [
            AcceleratorConfig::default(),
            AcceleratorConfig::dense_optimal(),
            AcceleratorConfig::foundry_baseline(),
        ] {
            let s = cfg.to_json();
            let back = AcceleratorConfig::from_json(&s).unwrap();
            assert_eq!(back.k1, cfg.k1);
            assert_eq!(back.l_s, cfg.l_s);
            assert_eq!(back.dac, cfg.dac);
            assert_eq!(back.mzi, cfg.mzi);
            assert_eq!(back.features, cfg.features);
        }
    }

    #[test]
    fn rejects_bad_sharing() {
        let cfg = AcceleratorConfig { share_r: 8, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = AcceleratorConfig { share_c: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_bad_eodac_partition() {
        let cfg = AcceleratorConfig {
            dac: DacKind::Eodac { segments: 2, bits_per_seg: 4 },
            b_in: 6,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_lr_without_ig() {
        let cfg = AcceleratorConfig {
            features: SparsitySupport {
                input_gating: false,
                output_gating: true,
                light_redistribution: true,
            },
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn pitch_includes_gap_and_width() {
        let cfg = AcceleratorConfig { l_s: 9.0, l_g: 5.0, ..Default::default() };
        assert!((cfg.l_h() - 20.0).abs() < 1e-12); // 5 + 9 + 6
    }

    #[test]
    fn chunk_shape_matches_sharing() {
        let cfg = AcceleratorConfig::default();
        assert_eq!(cfg.chunk_shape(), (64, 64));
    }
}

//! Reproduction-shape integration tests: run each paper harness at a small
//! sample budget and assert the paper's *qualitative* claims — who wins,
//! roughly by what factor, where the crossovers fall. (Full-budget tables:
//! `cargo run --release -- bench all`.)

use scatter::bench::{self, common::Workload, BenchCtx};
use scatter::config::{AcceleratorConfig, SparsitySupport};
use scatter::coordinator::EngineOptions;

fn ctx() -> BenchCtx {
    BenchCtx::new(30)
}

fn big_ctx() -> BenchCtx {
    BenchCtx::new(60)
}

/// Table 1 shape: every l_s row exists and accuracy stays within a few
/// points of the ideal (the paper's <1% criterion at full budget).
#[test]
fn table1_shape() {
    let t = bench::table1::run(&ctx());
    let s = t.render();
    assert_eq!(t.n_rows(), 5, "five l_s rows");
    assert!(s.contains("PAP"));
}

/// Table 2 shape: r=c=4 has the lowest power at every sparsity.
#[test]
fn table2_sharing_power_ordering() {
    let t = bench::table2::run(&ctx());
    let rows: Vec<Vec<f64>> = t
        .render()
        .lines()
        .skip(3)
        .map(|l| {
            l.split_whitespace()
                .filter_map(|c| c.parse::<f64>().ok())
                .collect::<Vec<f64>>()
        })
        .collect();
    assert_eq!(rows.len(), 3);
    // columns: r c P8 A8 P6 A6 P4 A4 — power falls monotonically with sharing
    for p_idx in [2usize, 4, 6] {
        assert!(
            rows[0][p_idx] > rows[1][p_idx] && rows[1][p_idx] > rows[2][p_idx],
            "sharing must reduce power (col {p_idx}): {:?}",
            rows.iter().map(|r| r[p_idx]).collect::<Vec<_>>()
        );
    }
}

/// Fig. 5 / Fig. 9(b) shape: prune-only ≥ IG ≥ IG+LR at every sparsity.
#[test]
fn fig5_mode_error_ordering() {
    let t = bench::fig5::run(&ctx());
    let mut rows: Vec<(f64, f64, f64)> = Vec::new();
    for line in t.render().lines().skip(3) {
        let vals: Vec<f64> =
            line.split_whitespace().filter_map(|c| c.parse::<f64>().ok()).collect();
        if vals.len() >= 3 {
            let (prune, ig, lr) = (vals[vals.len() - 3], vals[vals.len() - 2], vals[vals.len() - 1]);
            // weak ordering everywhere (noise ties allowed within 2%)...
            assert!(prune >= ig * 0.98, "prune {prune} >= IG {ig}: {line}");
            assert!(ig >= lr * 0.98, "IG {ig} >= LR {lr}: {line}");
            rows.push((prune, ig, lr));
        }
    }
    // ...and strict ordering in the sparsest regime, where LR's SNR gain
    // and the eliminated leakage dominate (paper Fig. 5 right / Fig. 9(b))
    let (prune, ig, lr) = *rows.last().expect("fig5 rows");
    assert!(prune > ig && ig > lr, "sparsest row must order strictly: {prune} {ig} {lr}");
}

/// Fig. 9(a) shape: with OG the interleaved pattern beats no-OG dense rows.
#[test]
fn fig9a_og_reduces_error() {
    let t = bench::fig9::run_a(&ctx());
    for line in t.render().lines().skip(3) {
        let vals: Vec<f64> =
            line.split_whitespace().filter_map(|c| c.parse::<f64>().ok()).collect();
        // pattern rows have [.., no_og, og]; sparse rows w/o OG are worse
        if vals.len() >= 2 && line.contains("interleaved") {
            let (no_og, og) = (vals[vals.len() - 2], vals[vals.len() - 1]);
            assert!(no_og > og, "OG must reduce error: {line}");
        }
    }
}

/// Fig. 10 shape: power and area fall monotonically along the waterfall
/// and the final step achieves large cumulative factors.
#[test]
fn fig10_waterfall_monotone_and_large() {
    let t = bench::fig10::run(&ctx());
    let mut pap = Vec::new();
    let mut area = Vec::new();
    let mut power = Vec::new();
    for line in t.render().lines().skip(3) {
        let cells: Vec<&str> = line.split_whitespace().collect();
        if cells.len() > 4 {
            if let (Ok(p), Ok(a), Ok(pp)) =
                (cells[1].parse::<f64>(), cells[2].parse::<f64>(), cells[3].parse::<f64>())
            {
                power.push(p);
                area.push(a);
                pap.push(pp);
            }
        }
    }
    assert_eq!(pap.len(), 8, "8 waterfall steps");
    // area never increases except the final eoDAC step (+2x DAC area)
    for i in 1..7 {
        assert!(
            area[i] <= area[i - 1] * 1.001,
            "area must fall through step {i}: {area:?}"
        );
    }
    // headline factors: orders of magnitude area, >5x power
    let area_factor = area[0] / area[7];
    let power_factor = power[0] / power[7];
    assert!(area_factor > 20.0, "area factor {area_factor}");
    assert!(power_factor > 4.0, "power factor {power_factor}");
    println!("fig10 factors: area {area_factor:.0}x, power {power_factor:.1}x");
}

/// Table 3 / e2e shape on CNN-3: dense degrades as l_g shrinks; SCATTER
/// with IG+OG+LR recovers to within a few points of ideal at l_g = 1 µm.
#[test]
fn table3_cnn3_recovery_shape() {
    let ctx = big_ctx();
    let n = 60;

    let acc = |l_g: f64, features: SparsitySupport, density: f64, opts: EngineOptions| {
        let cfg = AcceleratorConfig { l_g, features, ..Default::default() };
        let (model, ds, masks) = ctx.deployment(Workload::Cnn3, &cfg, density);
        ctx.accuracy(&model, &ds, &cfg, opts, masks, n).0
    };

    let ideal = acc(5.0, SparsitySupport::NONE, 1.0, EngineOptions::IDEAL);
    let dense_tv_1 = acc(1.0, SparsitySupport::NONE, 1.0, EngineOptions::NOISY);
    let dense_tv_5 = acc(5.0, SparsitySupport::NONE, 1.0, EngineOptions::NOISY);
    let sparse_ideal = acc(5.0, SparsitySupport::NONE, 0.3, EngineOptions::IDEAL);
    let scatter_rec = acc(1.0, SparsitySupport::FULL, 0.3, EngineOptions::NOISY);

    println!(
        "ideal {ideal:.2} dense@5 {dense_tv_5:.2} dense@1 {dense_tv_1:.2} \
         sparse-ideal {sparse_ideal:.2} scatter@1 {scatter_rec:.2}"
    );
    // paper CNN row: ideal 91.4, dense TV@1um 84.0 (~7 pt drop), SCATTER
    // ideal 91.56 with TV+IG+OG+LR 91.26 (recovers to its own ideal).
    // Accuracy deltas at this sample budget carry ~3-4 pt sampling noise,
    // so the degradation claim is additionally pinned on the
    // deterministic logit-error signal below.
    assert!(ideal > 0.6, "fitted model must work: {ideal}");
    assert!(dense_tv_1 <= ideal + 0.04, "TV cannot systematically help dense");
    let _ = dense_tv_5;
    assert!(sparse_ideal > 0.6, "s=0.3 deployment must stay functional: {sparse_ideal}");
    assert!(
        scatter_rec > sparse_ideal - 0.1,
        "IG+OG+LR must recover the sparse model to near its ideal: \
         {scatter_rec} vs {sparse_ideal}"
    );

    // deterministic hardware-degradation signal: dense logit N-MAE vs the
    // exact reference grows sharply as l_g shrinks 20 -> 1 um.
    let (model, ds) = ctx.fitted(Workload::Cnn3);
    let logit_err = |l_g: f64| {
        let cfg = AcceleratorConfig { l_g, features: SparsitySupport::NONE, ..Default::default() };
        let mut noisy = scatter::coordinator::PhotonicEngine::new(cfg, EngineOptions::NOISY);
        let mut exact = scatter::nn::ExactEngine;
        let mut acc = 0.0;
        for i in 0..5 {
            let (img, _) = ds.sample(0xD156, i);
            let y_noisy = model.forward(img.clone(), &mut noisy);
            let y_exact = model.forward(img, &mut exact);
            acc += scatter::util::nmae(&y_noisy.data, &y_exact.data);
        }
        acc / 5.0
    };
    let e1 = logit_err(1.0);
    let e20 = logit_err(20.0);
    println!("dense logit N-MAE: l_g=1um {e1:.3} vs l_g=20um {e20:.3}");
    assert!(
        e1 > 1.5 * e20,
        "crosstalk at l_g=1 must visibly corrupt dense logits: {e1} vs {e20}"
    );
}

/// Fig. 8: the eoDAC table contains the paper's 2.29x optimum.
#[test]
fn fig8_contains_optimum() {
    let t = bench::fig8::run(&ctx());
    let s = t.render();
    assert!(s.contains("2 x 3-bit"));
    assert!(s.contains("2.29x") || s.contains("2.28x"), "{s}");
}

/// Fig. 4: the heat-solver refit tracks the published fit within tolerance
/// over the physical range.
#[test]
fn fig4_heatsim_tracks_paper_fit() {
    let t = bench::fig4::run(&ctx());
    assert!(t.render().contains("gamma(d) heatsim"));
}

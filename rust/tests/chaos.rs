//! Deterministic chaos test (the PR's acceptance scenario): a seeded
//! [`FaultPlan`] kills every engine worker exactly once under concurrent
//! load. Conservation — every submitted request resolves to exactly one
//! terminal outcome, never a hang — plus full pool recovery and
//! same-seed reproducibility of both the kill schedule and the served
//! logits (IDEAL engines are replicas, so respawned workers cannot move
//! bits).

use scatter::config::{AcceleratorConfig, DacKind, SparsitySupport};
use scatter::coordinator::{
    EngineOptions, FaultPlan, InferenceServer, ServerConfig,
};
use scatter::nn::Tensor;
use std::sync::mpsc::RecvTimeoutError;
use std::time::Duration;

const SEED: u64 = 0xC0FFEE;
const WORKERS: usize = 3;
const CLIENTS: usize = 8;
const PER_CLIENT: usize = 8;

fn test_cfg() -> AcceleratorConfig {
    AcceleratorConfig {
        features: SparsitySupport::NONE,
        dac: DacKind::Edac,
        l_g: 5.0,
        ..Default::default()
    }
}

fn sample_img() -> Tensor {
    let ds = scatter::data::SyntheticDataset::new(scatter::data::DatasetSpec::fmnist_like());
    ds.sample(5, 0).0
}

#[test]
fn kill_schedule_is_bit_identical_across_reruns() {
    let a = FaultPlan::kill_each_worker_once(WORKERS, SEED);
    let b = FaultPlan::kill_each_worker_once(WORKERS, SEED);
    assert_eq!(a, b, "same seed, same plan");
    assert_eq!(a.describe(), b.describe());
    let c = FaultPlan::kill_each_worker_once(WORKERS, SEED + 1);
    assert_ne!(a.describe(), c.describe(), "seed actually drives the schedule");
}

#[test]
fn killing_every_worker_once_conserves_replies_and_restores_the_pool() {
    let server = InferenceServer::spawn(
        scatter::nn::models::cnn3(),
        test_cfg(),
        EngineOptions::IDEAL,
        Default::default(),
        ServerConfig::builder()
            .max_batch(6)
            .batch_timeout(Duration::from_millis(2))
            .workers(WORKERS)
            .engine_threads(1)
            .faults(FaultPlan::kill_each_worker_once(WORKERS, SEED))
            .build()
            .expect("chaos config validates"),
    );

    // closed-loop clients: each waits for its reply before submitting
    // the next, so load (and per-worker shard sequence numbers) keeps
    // advancing until every scheduled kill has fired
    let img = sample_img();
    let outcomes: Vec<(u64, u64, Vec<Vec<f64>>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|_| {
                let server = &server;
                let img = &img;
                s.spawn(move || {
                    let (mut ok, mut err) = (0u64, 0u64);
                    let mut logits = Vec::new();
                    for _ in 0..PER_CLIENT {
                        let rx = server.submit(img.clone()).expect("admitted");
                        match rx.recv_timeout(Duration::from_secs(120)) {
                            Ok(Ok(reply)) => {
                                assert_eq!(reply.logits.len(), 10);
                                assert!(reply.logits.iter().all(|v| v.is_finite()));
                                logits.push(reply.logits);
                                ok += 1;
                            }
                            // retry budget spent, or the request rode a
                            // channel-queued shard a dying worker never
                            // received: terminal, retryable, conserved
                            Ok(Err(_)) | Err(RecvTimeoutError::Disconnected) => err += 1,
                            Err(e @ RecvTimeoutError::Timeout) => {
                                panic!("reply neither served nor failed: {e:?}")
                            }
                        }
                    }
                    (ok, err, logits)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    let ok: u64 = outcomes.iter().map(|(o, _, _)| o).sum();
    let err: u64 = outcomes.iter().map(|(_, e, _)| e).sum();
    assert_eq!(
        ok + err,
        (CLIENTS * PER_CLIENT) as u64,
        "every request resolved exactly once"
    );
    assert!(ok > 0, "the pool kept serving through the kills");

    // IDEAL engines are deterministic replicas: every served reply for
    // the same image carries bit-identical logits, before and after
    // every respawn
    let mut all_logits = outcomes.iter().flat_map(|(_, _, l)| l.iter());
    if let Some(first) = all_logits.next() {
        for l in all_logits {
            assert_eq!(l, first, "a respawned replica moved bits");
        }
    }

    let report = server.shutdown().expect("drain");
    assert_eq!(report.requests as u64, ok, "report agrees with client-observed serves");
    assert_eq!(
        report.worker_restarts, WORKERS as u64,
        "each worker died once and was respawned once"
    );
    assert_eq!(report.workers_live, WORKERS, "pool back at full strength");
    assert!(report.request_retries >= WORKERS as u64, "every kill forced re-dispatch");
}

//! Torture tests for the epoll reactor front-end: slow-loris clients,
//! mid-body disconnects, oversize uploads, and a keep-alive connection
//! storm — all against a real listener, with `/proc/self` assertions
//! that connections are reclaimed (fd count) and that the reactor stays
//! one thread (task count), not thread-per-connection.
//!
//! Linux-only: the assertions read `/proc/self/fd` and
//! `/proc/self/task`, and the reactor's production path is the epoll
//! poller. Other platforms compile this file to nothing.
#![cfg(target_os = "linux")]

use scatter::config::{AcceleratorConfig, DacKind, SparsitySupport};
use scatter::coordinator::net::{http_request, HttpServer, NetConfig};
use scatter::coordinator::{EngineOptions, InferenceServer, ServerConfig};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// The fd/thread assertions count process-wide state, so the tests in
/// this binary must not overlap.
static SERIAL: Mutex<()> = Mutex::new(());

fn test_cfg() -> AcceleratorConfig {
    AcceleratorConfig {
        features: SparsitySupport::NONE,
        dac: DacKind::Edac,
        l_g: 5.0,
        ..Default::default()
    }
}

fn spawn_http(net: NetConfig) -> HttpServer {
    let server = InferenceServer::spawn(
        scatter::nn::models::cnn3(),
        test_cfg(),
        EngineOptions::IDEAL,
        Default::default(),
        ServerConfig::builder()
            .max_batch(4)
            .batch_timeout(Duration::from_millis(1))
            .workers(1)
            .build()
            .expect("test config validates"),
    );
    HttpServer::bind(server, net).expect("bind ephemeral port")
}

fn open_fds() -> usize {
    std::fs::read_dir("/proc/self/fd").expect("procfs").count()
}

fn live_threads() -> usize {
    std::fs::read_dir("/proc/self/task").expect("procfs").count()
}

/// Poll until `pred` holds or `timeout` elapses; returns success.
fn eventually(timeout: Duration, mut pred: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if pred() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    pred()
}

/// Read until the peer closes; the reactor marks every torture-path
/// response `Connection: close`, so EOF delimits it.
fn read_to_eof(stream: &mut TcpStream) -> String {
    let mut buf = Vec::new();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .expect("read timeout");
    let _ = stream.read_to_end(&mut buf);
    String::from_utf8_lossy(&buf).into_owned()
}

/// A request trickled in 3-byte chunks still parses and gets its
/// response: the reactor accumulates partial reads across ticks instead
/// of blocking a thread on the socket.
#[test]
fn slow_loris_request_still_completes() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let http = spawn_http(NetConfig::default());
    let addr: SocketAddr = http.local_addr();

    let request = b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n";
    let mut stream = TcpStream::connect(addr).expect("connect");
    for chunk in request.chunks(3) {
        stream.write_all(chunk).expect("trickle");
        stream.flush().expect("flush");
        std::thread::sleep(Duration::from_millis(5));
    }
    let resp = read_to_eof(&mut stream);
    assert!(resp.starts_with("HTTP/1.1 200"), "loris got a real response: {resp}");
    assert!(resp.contains("\"status\":\"ok\""), "{resp}");

    http.shutdown().expect("drain");
}

/// A client that dies mid-body must not leak its connection: the
/// reactor sees the hangup, drops the state, and the fd count returns
/// to where it was. The server keeps serving afterwards.
#[test]
fn mid_body_disconnect_reclaims_the_connection() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let http = spawn_http(NetConfig::default());
    let addr: SocketAddr = http.local_addr();
    // settle: the listener and engine threads are all up
    assert!(http_request(&addr, "GET", "/healthz", None).is_ok());
    let baseline = open_fds();

    {
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream
            .write_all(
                b"POST /v1/predict HTTP/1.1\r\nHost: t\r\n\
                  Content-Type: application/json\r\nContent-Length: 100000\r\n\r\n{\"image\":[",
            )
            .expect("partial body");
        stream.flush().expect("flush");
        // give the reactor a tick to register and start reading
        std::thread::sleep(Duration::from_millis(50));
    } // dropped mid-body: RST/EOF at the server

    assert!(
        eventually(Duration::from_secs(10), || open_fds() <= baseline),
        "abandoned connection must be reclaimed: {} fds vs baseline {baseline}",
        open_fds()
    );
    // and the reactor is still serving
    let health = http_request(&addr, "GET", "/healthz", None).expect("alive");
    assert_eq!(health.status, 200);

    http.shutdown().expect("drain");
}

/// A body larger than the request cap gets the 413 envelope as soon as
/// the buffered bytes cross the limit — the client need not finish the
/// upload (it stops early here, so the response is never lost to a
/// reset race).
#[test]
fn oversize_body_gets_413_envelope() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let http = spawn_http(NetConfig::default());
    let addr: SocketAddr = http.local_addr();

    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .write_all(
            b"POST /v1/predict HTTP/1.1\r\nHost: t\r\n\
              Content-Type: application/json\r\nContent-Length: 6000000\r\n\r\n",
        )
        .expect("head");
    // push past the 4 MiB cap, then stop and listen
    let filler = vec![b'1'; 64 * 1024];
    for _ in 0..70 {
        if stream.write_all(&filler).is_err() {
            break; // server already rejected and closed — fine
        }
    }
    let resp = read_to_eof(&mut stream);
    assert!(resp.starts_with("HTTP/1.1 413"), "oversize upload rejected: {resp}");
    assert!(resp.contains("\"code\":\"payload_too_large\""), "{resp}");
    assert!(resp.contains("\"retryable\":false"), "{resp}");

    http.shutdown().expect("drain");
}

/// Hundreds of concurrent keep-alive connections are held open and
/// served by ONE reactor thread: the process thread count stays flat
/// (thread-per-connection would add one each), every connection gets
/// its responses, and closing them returns the fd count to baseline.
#[test]
fn keep_alive_storm_holds_on_one_thread() {
    const CONNS: usize = 256;
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let http = spawn_http(NetConfig { max_connections: CONNS + 8, ..Default::default() });
    let addr: SocketAddr = http.local_addr();
    assert!(http_request(&addr, "GET", "/healthz", None).is_ok());
    let fd_baseline = open_fds();
    let thread_baseline = live_threads();

    let mut conns = Vec::with_capacity(CONNS);
    for i in 0..CONNS {
        let stream = TcpStream::connect(addr).unwrap_or_else(|e| panic!("conn {i}: {e}"));
        conns.push(stream);
    }
    // every connection speaks once (keep-alive: the reactor must hold
    // all of them open simultaneously, not serve-and-close)
    let req = b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n";
    for (i, stream) in conns.iter_mut().enumerate() {
        stream.write_all(req).unwrap_or_else(|e| panic!("write {i}: {e}"));
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .expect("read timeout");
        let mut buf = [0u8; 4096];
        let n = stream.read(&mut buf).unwrap_or_else(|e| panic!("read {i}: {e}"));
        let head = String::from_utf8_lossy(&buf[..n]);
        assert!(head.starts_with("HTTP/1.1 200"), "conn {i}: {head}");
    }

    assert!(
        open_fds() >= fd_baseline + CONNS,
        "all {CONNS} connections are held open concurrently"
    );
    assert!(
        live_threads() <= thread_baseline + 4,
        "the reactor serves {CONNS} connections without per-connection threads: \
         {} threads vs baseline {thread_baseline}",
        live_threads()
    );

    drop(conns);
    assert!(
        eventually(Duration::from_secs(10), || open_fds() <= fd_baseline),
        "closed connections must be reclaimed: {} fds vs baseline {fd_baseline}",
        open_fds()
    );

    http.shutdown().expect("drain");
}

/// The same request split at EVERY byte boundary — including between
/// the `\r` and `\n` of each CRLF, the classic parser-state bug — must
/// parse identically: the reactor's head accumulator cannot care where
/// the kernel happened to cut the stream.
#[test]
fn headers_split_at_every_byte_boundary_still_parse() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let http = spawn_http(NetConfig::default());
    let addr: SocketAddr = http.local_addr();

    let request = b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n";
    for cut in 1..request.len() {
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream.write_all(&request[..cut]).unwrap_or_else(|e| panic!("cut {cut}: {e}"));
        stream.flush().expect("flush");
        // let the reactor consume the fragment on its own tick first
        std::thread::sleep(Duration::from_millis(2));
        stream.write_all(&request[cut..]).unwrap_or_else(|e| panic!("cut {cut}: {e}"));
        let resp = read_to_eof(&mut stream);
        assert!(
            resp.starts_with("HTTP/1.1 200"),
            "split at byte {cut} must not confuse the parser: {resp}"
        );
    }

    http.shutdown().expect("drain");
}

/// Two requests pipelined into one write get two responses on the same
/// keep-alive connection: the reactor must not discard the second
/// request's bytes after parsing the first.
#[test]
fn pipelined_requests_in_one_write_both_answered() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let http = spawn_http(NetConfig::default());
    let addr: SocketAddr = http.local_addr();

    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .write_all(
            b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n\
              GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
        )
        .expect("pipelined pair");
    let resp = read_to_eof(&mut stream);
    assert_eq!(
        resp.matches("HTTP/1.1 200").count(),
        2,
        "both pipelined requests answered: {resp}"
    );

    http.shutdown().expect("drain");
}

/// A zero-length POST body reaches the handler immediately (no waiting
/// for bytes that will never come) and gets the 400 envelope — not a
/// hang, not a connection drop.
#[test]
fn zero_length_post_body_gets_prompt_400() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let http = spawn_http(NetConfig::default());
    let addr: SocketAddr = http.local_addr();

    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .write_all(
            b"POST /v1/predict HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\
              Connection: close\r\n\r\n",
        )
        .expect("empty post");
    let started = Instant::now();
    let resp = read_to_eof(&mut stream);
    assert!(resp.starts_with("HTTP/1.1 400"), "empty body rejected: {resp}");
    assert!(resp.contains("\"code\":\"bad_request\""), "{resp}");
    assert!(
        started.elapsed() < Duration::from_secs(10),
        "zero-length body must not wait on a read timeout"
    );

    http.shutdown().expect("drain");
}

/// A garbage byte stream — not HTTP at all — gets the structured 400
/// envelope and a close, and the reactor survives to serve the next
/// client (the request path is panic-proof against arbitrary input).
#[test]
fn garbage_byte_stream_gets_400_envelope_and_server_survives() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let http = spawn_http(NetConfig::default());
    let addr: SocketAddr = http.local_addr();

    let mut stream = TcpStream::connect(addr).expect("connect");
    let mut garbage = vec![0u8, 0xff, 0x13, 0x37];
    garbage.extend_from_slice("\u{1F4A3} not http \u{0000}".as_bytes());
    garbage.extend_from_slice(b"\r\n\r\n");
    stream.write_all(&garbage).expect("garbage");
    let resp = read_to_eof(&mut stream);
    assert!(resp.starts_with("HTTP/1.1 400"), "garbage rejected cleanly: {resp}");
    assert!(resp.contains("\"code\":\"bad_request\""), "{resp}");

    // an adversarial shape that would overflow usize answers 400 too
    let evil = format!(
        "{{\"image\": [1.0, 2.0], \"shape\": [2, {}]}}",
        usize::MAX
    );
    let resp = http_request(&addr, "POST", "/v1/predict", Some(&evil)).expect("alive");
    assert_eq!(resp.status, 400, "overflowing shape is a 400, not a panic: {resp:?}");
    assert!(resp.body.contains("bad_request"), "{resp:?}");

    // and an honest client is still served
    let health = http_request(&addr, "GET", "/healthz", None).expect("alive");
    assert_eq!(health.status, 200);

    http.shutdown().expect("drain");
}

/// Connections beyond `max_connections` get one `overloaded` 503
/// envelope and are closed — and those rejected sockets are reclaimed
/// too.
#[test]
fn connections_beyond_the_cap_get_a_503_envelope() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let http = spawn_http(NetConfig { max_connections: 4, ..Default::default() });
    let addr: SocketAddr = http.local_addr();
    assert!(http_request(&addr, "GET", "/healthz", None).is_ok());
    let baseline = open_fds();

    // fill the table with idle keep-alive connections
    let holders: Vec<TcpStream> =
        (0..4).map(|i| TcpStream::connect(addr).unwrap_or_else(|e| panic!("{i}: {e}"))).collect();
    // give the reactor a tick to accept them all
    std::thread::sleep(Duration::from_millis(100));

    let mut extra = TcpStream::connect(addr).expect("connect past cap");
    extra
        .write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n")
        .expect("request past cap");
    let resp = read_to_eof(&mut extra);
    assert!(resp.starts_with("HTTP/1.1 503"), "over-cap connection rejected: {resp}");
    assert!(resp.contains("\"code\":\"overloaded\""), "{resp}");
    assert!(resp.contains("\"retryable\":true"), "{resp}");
    assert!(resp.contains("Retry-After:"), "{resp}");

    drop(extra);
    drop(holders);
    assert!(
        eventually(Duration::from_secs(10), || open_fds() <= baseline),
        "rejected + held connections all reclaimed: {} vs baseline {baseline}",
        open_fds()
    );

    http.shutdown().expect("drain");
}

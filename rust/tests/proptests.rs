//! Hand-rolled property tests (the offline toolchain has no proptest):
//! randomized invariants on the coordinator's routing/batching/state and
//! the sparsity/rerouter substrates, driven by the deterministic
//! `XorShiftRng`. Each property runs across many random cases; failures
//! print the case seed for replay.

use scatter::config::{AcceleratorConfig, DacKind, SparsitySupport};
use scatter::coordinator::Scheduler;
use scatter::devices::{Mzi, MziSpec};
use scatter::rerouter::RerouterTree;
use scatter::sparsity::{best_segment_mask, interleaved_row_mask, ChunkMask, LayerMask};
use scatter::thermal::GammaModel;
use scatter::util::XorShiftRng;

const CASES: usize = 200;

fn rand_cfg(rng: &mut XorShiftRng) -> AcceleratorConfig {
    let shares = [1usize, 2, 4];
    AcceleratorConfig {
        share_r: shares[rng.index(3)],
        share_c: shares[rng.index(3)],
        l_g: [1.0, 3.0, 5.0, 20.0][rng.index(4)],
        dac: if rng.uniform() < 0.5 { DacKind::Edac } else { DacKind::optimal_eodac() },
        features: SparsitySupport::FULL,
        ..Default::default()
    }
}

/// Every chunk of every schedule is assigned exactly once, slots never
/// collide within a wave, and wall cycles == waves × cols.
#[test]
fn prop_scheduler_covers_all_chunks_without_slot_collisions() {
    let mut rng = XorShiftRng::new(0x5C4ED);
    for case in 0..CASES {
        let cfg = rand_cfg(&mut rng);
        let sched = Scheduler::new(cfg.clone());
        let out_dim = 1 + rng.index(400);
        let in_dim = 1 + rng.index(800);
        let ls = sched.schedule(out_dim, in_dim);
        assert_eq!(ls.assignments.len(), ls.p * ls.q, "case {case}");
        // coverage: each (pi, qi) exactly once
        let mut seen = vec![false; ls.p * ls.q];
        for a in &ls.assignments {
            let idx = a.pi * ls.q + a.qi;
            assert!(!seen[idx], "case {case}: duplicate chunk ({}, {})", a.pi, a.qi);
            seen[idx] = true;
            assert!(a.slot < ls.slots, "case {case}: slot out of range");
        }
        assert!(seen.iter().all(|&s| s), "case {case}: chunk not scheduled");
        // no slot collision within a wave
        for w in 0..ls.n_waves() {
            let mut used = vec![false; ls.slots];
            for a in ls.assignments.iter().filter(|a| a.wave == w) {
                assert!(!used[a.slot], "case {case}: slot reuse in wave {w}");
                used[a.slot] = true;
            }
        }
        // padding covers the matrix
        assert!(ls.p * ls.chunk_rows >= out_dim);
        assert!(ls.q * ls.chunk_cols >= in_dim);
        let n_cols = 1 + rng.index(100);
        assert_eq!(ls.wall_cycles(n_cols), (ls.n_waves() * n_cols) as u64);
    }
}

/// The rerouter conserves optical power and steers it only to active
/// leaves, for arbitrary masks.
#[test]
fn prop_rerouter_conserves_and_targets_power() {
    let mut rng = XorShiftRng::new(0x11E1);
    for case in 0..CASES {
        let k = [2usize, 4, 8, 16, 32][rng.index(5)];
        let mask: Vec<bool> = (0..k).map(|_| rng.uniform() < 0.5).collect();
        let tree = RerouterTree::program(&mask);
        let powers = tree.leaf_powers();
        let active = mask.iter().filter(|&&m| m).count();
        let total: f64 = powers.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "case {case}: power not conserved");
        if active > 0 {
            for (j, (&p, &m)) in powers.iter().zip(&mask).enumerate() {
                if m {
                    assert!(
                        (p - 1.0 / active as f64).abs() < 1e-9,
                        "case {case}: leaf {j} power {p}"
                    );
                } else {
                    assert!(p.abs() < 1e-12, "case {case}: pruned leaf {j} gets {p}");
                }
            }
        }
        assert_eq!(tree.active_leaves(), active);
    }
}

/// best_segment_mask never loses to a random mask of equal cardinality.
#[test]
fn prop_power_opt_beats_random_masks() {
    let mut rng = XorShiftRng::new(0xBEA7);
    let mzi = Mzi::new(MziSpec::low_power(), 9.0, &GammaModel::paper());
    for case in 0..50 {
        let k = [8usize, 16][rng.index(2)];
        let n_active = 1 + rng.index(k - 1);
        let best = best_segment_mask(k, n_active, &mzi, 1_000_000);
        let p_best = scatter::sparsity::mask_power_mw(&best, k, &mzi);
        for _ in 0..20 {
            let mut mask = vec![false; k];
            let mut idx: Vec<usize> = (0..k).collect();
            rng.shuffle(&mut idx);
            for &i in idx.iter().take(n_active) {
                mask[i] = true;
            }
            let p = scatter::sparsity::mask_power_mw(&mask, k, &mzi);
            assert!(
                p >= p_best - 1e-12,
                "case {case}: random mask beat the optimum ({p} < {p_best})"
            );
        }
    }
}

/// Interleaved row masks never place two zeros adjacently and hit the
/// requested cardinality, for any density in [0.5, 1].
#[test]
fn prop_interleaved_rows_isolated_zeros() {
    let mut rng = XorShiftRng::new(0x1A7E);
    for _ in 0..CASES {
        let n = 2 * (1 + rng.index(32));
        let density = rng.uniform_in(0.5, 1.0);
        let mask = interleaved_row_mask(n, density);
        let expected_ones = n - ((1.0 - density) * n as f64).round() as usize;
        assert_eq!(mask.iter().filter(|&&m| m).count(), expected_ones);
        for i in 0..n - 1 {
            assert!(mask[i] || mask[i + 1], "adjacent zeros at {i} (n={n})");
        }
    }
}

/// Mask JSON round-trips for arbitrary layer masks.
#[test]
fn prop_mask_json_roundtrip() {
    let mut rng = XorShiftRng::new(0x70B1);
    for case in 0..CASES {
        let p = 1 + rng.index(3);
        let q = 1 + rng.index(3);
        let rows = 4 * (1 + rng.index(8));
        let cols = 4 * (1 + rng.index(8));
        let chunks: Vec<ChunkMask> = (0..p * q)
            .map(|_| {
                ChunkMask::new(
                    (0..rows).map(|_| rng.uniform() < 0.7).collect(),
                    (0..cols).map(|_| rng.uniform() < 0.7).collect(),
                )
            })
            .collect();
        let lm = LayerMask { p, q, chunks };
        let json = lm.to_json().to_string();
        let back = LayerMask::from_json(&scatter::util::Json::parse(&json).unwrap())
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(back.chunks, lm.chunks, "case {case}");
        assert_eq!(back.density(), lm.density());
    }
}

/// Config JSON round-trips across random configurations.
#[test]
fn prop_config_json_roundtrip() {
    let mut rng = XorShiftRng::new(0xC0F6);
    for case in 0..CASES {
        let cfg = rand_cfg(&mut rng);
        let back = AcceleratorConfig::from_json(&cfg.to_json())
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(back.share_r, cfg.share_r, "case {case}");
        assert_eq!(back.share_c, cfg.share_c);
        assert_eq!(back.l_g, cfg.l_g);
        assert_eq!(back.dac, cfg.dac);
        assert_eq!(back.features, cfg.features);
    }
}

/// The CSR fast path of `CouplingModel::perturb_phases` must equal the
/// dense Eq.-8 mat-vec over the exported `matrices()` —
/// `Δφ̃ = Δφ + G⁺·max(Δφ, 0) + G⁻·max(−Δφ, 0)` — for random
/// geometries and phase vectors (the AOT/Pallas path consumes the dense
/// matrices, so divergence here would split the two backends).
#[test]
fn prop_coupling_csr_matches_dense_matvec() {
    use scatter::thermal::coupling::{ArrayGeometry, CouplingModel};
    use std::f64::consts::FRAC_PI_2;
    let mut rng = XorShiftRng::new(0xC58D);
    let gamma = GammaModel::paper();
    for case in 0..80 {
        let rows = 1 + rng.index(4);
        let cols = 2 + rng.index(7);
        let geom = ArrayGeometry {
            rows,
            cols,
            l_v: rng.uniform_in(100.0, 140.0),
            l_h: rng.uniform_in(14.0, 40.0),
            l_s: rng.uniform_in(7.0, 11.0),
        };
        let m = CouplingModel::new(geom, &gamma);
        let n = rows * cols;
        let (g_pos, g_neg) = m.matrices();
        let mut phases = vec![0.0f64; n];
        rng.fill_uniform(&mut phases, -FRAC_PI_2, FRAC_PI_2);
        // sprinkle exact zeros and sign boundaries into the vector
        for j in 0..n {
            if rng.uniform() < 0.2 {
                phases[j] = 0.0;
            }
        }
        let csr = m.perturbed(&phases);
        for i in 0..n {
            let mut dense = phases[i];
            for j in 0..n {
                dense += g_pos[i * n + j] * phases[j].max(0.0)
                    + g_neg[i * n + j] * (-phases[j]).max(0.0);
            }
            assert!(
                (csr[i] - dense).abs() < 1e-12,
                "case {case}: victim {i} CSR {} vs dense {dense}",
                csr[i]
            );
        }
    }
}

/// Drifted-then-recalibrated engines must match never-drifted engines
/// **exactly** on every output, across random shapes, masks, drift
/// times, and worker ids — the property that makes online
/// recalibration indistinguishable from a fresh `program_layer` while
/// recompiling only the affected chunks.
#[test]
fn prop_drift_recalibrated_matches_fresh_bit_for_bit() {
    use scatter::coordinator::{EngineOptions, PhotonicEngine};
    use scatter::nn::MatmulEngine;
    use scatter::thermal::{DriftConfig, ThermalPolicy};
    use std::collections::BTreeMap;
    let mut rng = XorShiftRng::new(0xD21F7A);
    let opts =
        EngineOptions { thermal: true, pd_noise: false, phase_noise: false, quantize: true };
    for case in 0..12 {
        let cfg = AcceleratorConfig {
            features: SparsitySupport::FULL,
            l_g: [1.0, 5.0][rng.index(2)],
            ..Default::default()
        };
        let (rows, cols) = cfg.chunk_shape();
        let out_dim = rows + rng.index(rows * 2);
        let in_dim = cols + rng.index(cols * 2);
        let n_cols = 1 + rng.index(4);
        let mut w = vec![0.0; out_dim * in_dim];
        rng.fill_uniform(&mut w, -0.5, 0.5);
        let mut x = vec![0.0; in_dim * n_cols];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        let p = out_dim.div_ceil(rows);
        let q = in_dim.div_ceil(cols);
        let chunks: Vec<ChunkMask> = (0..p * q)
            .map(|_| {
                ChunkMask::new(
                    (0..rows).map(|_| rng.uniform() < 0.7).collect(),
                    (0..cols).map(|_| rng.uniform() < 0.6).collect(),
                )
            })
            .collect();
        let mask = LayerMask { p, q, chunks };
        let build = |with_thermal: bool| {
            let mut eng = PhotonicEngine::new(cfg.clone(), opts);
            let mut masks = BTreeMap::new();
            masks.insert("l".to_string(), mask.clone());
            eng.set_masks(masks);
            if with_thermal {
                eng.set_thermal(
                    DriftConfig {
                        worker_id: case as u64,
                        ..DriftConfig::accelerated()
                    },
                    ThermalPolicy::Off,
                );
            }
            eng
        };
        let mut fresh = build(false);
        let mut drifted = build(true);
        let y_fresh = fresh.matmul("l", &w, &x, out_dim, in_dim, n_cols);
        let y0 = drifted.matmul("l", &w, &x, out_dim, in_dim, n_cols);
        assert_eq!(y_fresh, y0, "case {case}: un-ticked runtime must be inert");
        // drift to a random point in the schedule, then recalibrate
        let t = rng.uniform_in(1.0, 90.0);
        let served = 1 + rng.index(200) as u64;
        let status = drifted.thermal_tick(t, served).expect("runtime on");
        let y_drift = drifted.matmul("l", &w, &x, out_dim, in_dim, n_cols);
        let recal = drifted.recalibrate_thermal();
        let y_recal = drifted.matmul("l", &w, &x, out_dim, in_dim, n_cols);
        assert_eq!(
            y_fresh, y_recal,
            "case {case}: recalibrated output must match fresh programming bit-for-bit"
        );
        // when the schedule actually moved the phases, the drifted
        // output differed and recalibration touched every chunk
        if status.env_rad.abs() * 0.2 > 1e-3 {
            assert_ne!(y_drift, y_fresh, "case {case}: drift must be visible");
            assert!(recal > 0, "case {case}: recalibration must recompile chunks");
        }
    }
}

/// Programmed-PTC streaming equals the one-shot forward for random
/// problems, masks, and modes (noise off: bitwise determinism).
#[test]
fn prop_programmed_equals_forward() {
    use scatter::devices::DeviceLibrary;
    use scatter::ptc::crossbar::{ColumnMode, ForwardOptions, PtcSimulator};
    use scatter::thermal::coupling::ArrayGeometry;
    let mut rng = XorShiftRng::new(0xF00D);
    let gamma = GammaModel::paper();
    for case in 0..60 {
        let k = [4usize, 8, 16][rng.index(3)];
        let geom = ArrayGeometry {
            rows: k,
            cols: k,
            l_v: 120.0,
            l_h: rng.uniform_in(16.0, 40.0),
            l_s: rng.uniform_in(7.0, 11.0),
        };
        let sim = PtcSimulator::new(geom, &gamma, DeviceLibrary::default());
        let mut w = vec![0.0; k * k];
        rng.fill_uniform(&mut w, -1.0, 1.0);
        let mut x = vec![0.0; k];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        let col_mask: Vec<bool> = (0..k).map(|_| rng.uniform() < 0.6).collect();
        let row_mask: Vec<bool> = (0..k).map(|_| rng.uniform() < 0.6).collect();
        let mode = [ColumnMode::PruneOnly, ColumnMode::InputGating, ColumnMode::InputGatingLr]
            [rng.index(3)];
        let opts = ForwardOptions {
            thermal: true,
            col_mask: Some(&col_mask),
            row_mask: Some(&row_mask),
            col_mode: mode,
            output_gating: rng.uniform() < 0.5,
            ..Default::default()
        };
        let y_fwd = sim.forward(&w, &x, &opts, &mut XorShiftRng::new(0));
        let mut prog = sim.program(&w, &opts, &mut XorShiftRng::new(0));
        let y_prog = prog.run(&x, &mut XorShiftRng::new(0));
        for i in 0..k {
            assert!(
                (y_fwd[i] - y_prog[i]).abs() < 1e-12,
                "case {case}: output {i} differs ({} vs {})",
                y_fwd[i],
                y_prog[i]
            );
        }
    }
}

//! End-to-end tests of the networked inference front-end: a real
//! `TcpListener` on an ephemeral port, concurrent `POST /v1/predict`
//! clients, admission-control conservation (every request gets exactly
//! one reply or a 503), the structured error envelope on every 4xx/5xx
//! path, live `/metrics`, health degradation under injected worker
//! faults, and graceful drain.

use scatter::config::{AcceleratorConfig, DacKind, SparsitySupport};
use scatter::coordinator::net::{
    http_request, metric_value, HttpClient, HttpServer, NetConfig,
};
use scatter::coordinator::{
    EngineOptions, FaultPlan, InferenceServer, ServerConfig,
};
use scatter::util::Json;
use std::time::Duration;

/// Every non-200 from the API must carry the structured envelope
/// `{"error":{"code","message","retryable"}}`; 503s additionally carry
/// `retry_after_s` mirroring the Retry-After header. Returns the code.
fn assert_envelope(body: &str, status: u16, want_code: &str, want_retryable: bool) {
    let v = Json::parse(body).unwrap_or_else(|e| panic!("{status} body not JSON ({e}): {body}"));
    let err = v.get("error").unwrap_or_else(|| panic!("{status} body has no error: {body}"));
    assert_eq!(
        err.get("code").and_then(Json::as_str),
        Some(want_code),
        "{status} code: {body}"
    );
    assert!(
        !err.get("message").and_then(Json::as_str).unwrap_or("").is_empty(),
        "{status} message must be non-empty: {body}"
    );
    assert_eq!(
        err.get("retryable").and_then(Json::as_bool),
        Some(want_retryable),
        "{status} retryable: {body}"
    );
    if status == 503 {
        assert!(
            err.get("retry_after_s").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0,
            "503 envelope carries retry_after_s: {body}"
        );
    } else {
        assert!(err.get("retry_after_s").is_none(), "only 503 hints a retry: {body}");
    }
}

fn test_cfg() -> AcceleratorConfig {
    AcceleratorConfig {
        features: SparsitySupport::NONE,
        dac: DacKind::Edac,
        l_g: 5.0,
        ..Default::default()
    }
}

fn spawn_http_cfg(server_cfg: ServerConfig) -> HttpServer {
    let server = InferenceServer::spawn(
        scatter::nn::models::cnn3(),
        test_cfg(),
        EngineOptions::IDEAL,
        Default::default(),
        server_cfg,
    );
    HttpServer::bind(server, NetConfig::default()).expect("bind ephemeral port")
}

fn spawn_http(max_in_flight: usize, workers: usize) -> HttpServer {
    spawn_http_cfg(
        ServerConfig::builder()
            .max_batch(8)
            .batch_timeout(Duration::from_millis(1))
            .workers(workers)
            .engine_threads(1)
            .max_in_flight(max_in_flight)
            .build()
            .expect("test config validates"),
    )
}

fn predict_body() -> String {
    let ds = scatter::data::SyntheticDataset::new(scatter::data::DatasetSpec::fmnist_like());
    let (img, _) = ds.sample(3, 0);
    Json::obj(vec![("image", Json::arr_f64(&img.data))]).to_string()
}

#[test]
fn http_end_to_end_concurrent_load() {
    let http = spawn_http(16, 2);
    let addr = http.local_addr();
    let body = predict_body();

    // healthz before load
    let health = http_request(&addr, "GET", "/healthz", None).expect("healthz");
    assert_eq!(health.status, 200);
    assert!(health.body.contains("\"status\":\"ok\""), "{}", health.body);

    // 32 concurrent client threads, 2 requests each, over a 16-slot cap:
    // every request must get exactly one terminal answer — a 200 with
    // sane logits, or an admission 503 carrying Retry-After
    let (ok, shed): (usize, usize) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..32)
            .map(|_| {
                let body = &body;
                s.spawn(move || {
                    let (mut ok, mut shed) = (0usize, 0usize);
                    for _ in 0..2 {
                        let resp = http_request(&addr, "POST", "/v1/predict", Some(body))
                            .expect("one reply per request");
                        match resp.status {
                            200 => {
                                let v = Json::parse(&resp.body).expect("json");
                                let logits =
                                    v.get("logits").and_then(Json::f64_vec).expect("logits");
                                assert_eq!(logits.len(), 10);
                                assert!(v.get("class").and_then(Json::as_usize).unwrap() < 10);
                                assert!(
                                    v.get("latency_us").and_then(Json::as_f64).unwrap() > 0.0
                                );
                                assert!(
                                    v.get("energy_mj").and_then(Json::as_f64).unwrap() > 0.0,
                                    "every 200 carries its batched-pass energy share"
                                );
                                ok += 1;
                            }
                            503 => {
                                assert!(
                                    resp.retry_after_s.unwrap_or(0) >= 1,
                                    "503 must carry Retry-After"
                                );
                                assert_envelope(&resp.body, 503, "overloaded", true);
                                shed += 1;
                            }
                            other => panic!("unexpected status {other}: {}", resp.body),
                        }
                    }
                    (ok, shed)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .fold((0, 0), |(a, b), (c, d)| (a + c, b + d))
    });
    assert_eq!(ok + shed, 64, "every request answered exactly once");
    assert!(ok > 0, "some requests must be served");

    // live metrics expose nonzero latency + energy counters
    let m = http_request(&addr, "GET", "/metrics", None).expect("metrics");
    assert_eq!(m.status, 200);
    assert_eq!(metric_value(&m.body, "scatter_requests_total"), ok as f64);
    assert_eq!(metric_value(&m.body, "scatter_shed_total"), shed as f64);
    assert!(
        metric_value(&m.body, "scatter_request_latency_microseconds{quantile=\"0.5\"}") > 0.0,
        "p50 latency must be nonzero:\n{}",
        m.body
    );
    assert!(
        metric_value(&m.body, "scatter_request_latency_microseconds{quantile=\"0.99\"}")
            >= metric_value(&m.body, "scatter_request_latency_microseconds{quantile=\"0.5\"}")
    );
    assert!(
        metric_value(&m.body, "scatter_energy_millijoules_total") > 0.0,
        "energy counter must be nonzero:\n{}",
        m.body
    );
    assert!(metric_value(&m.body, "scatter_p_avg_watts") > 0.0);
    assert_eq!(metric_value(&m.body, "scatter_queue_depth"), 0.0, "idle after load");

    // kernel-variant info gauge: default precision is exact, and the
    // variant label reflects runtime SIMD detection
    assert_eq!(metric_value(&m.body, "scatter_kernel_variant{"), 1.0);
    assert!(
        m.body.contains(&format!(
            "scatter_kernel_variant{{variant=\"{}\",precision=\"exact\"}} 1",
            scatter::exec::detected_simd().as_str()
        )),
        "kernel gauge must carry variant + precision labels:\n{}",
        m.body
    );

    // mask hot-swap series are always exported; with DST off they sit
    // at the deployment baseline
    assert_eq!(metric_value(&m.body, "scatter_mask_generation{worker=\"0\"}"), 0.0);
    assert_eq!(metric_value(&m.body, "scatter_mask_swaps_total"), 0.0);
    assert_eq!(metric_value(&m.body, "scatter_mask_rollbacks_total"), 0.0);

    // batch-occupancy histogram: every dispatched batch is observed,
    // buckets are cumulative, and the mean is derivable from sum/count
    let occ_count = metric_value(&m.body, "scatter_batch_occupancy_count");
    let occ_sum = metric_value(&m.body, "scatter_batch_occupancy_sum");
    let occ_inf = metric_value(&m.body, "scatter_batch_occupancy_bucket{le=\"+Inf\"}");
    assert!(occ_count > 0.0, "batches must register in the histogram:\n{}", m.body);
    assert_eq!(occ_inf, occ_count, "+Inf bucket equals count");
    assert_eq!(occ_sum, ok as f64, "every served request rode in some batch");
    assert!(
        metric_value(&m.body, "scatter_batch_occupancy_bucket{le=\"8\"}") <= occ_count,
        "buckets are cumulative and bounded by count"
    );

    // graceful drain: the final report agrees with what clients saw
    let report = http.shutdown().expect("drain");
    assert_eq!(report.requests, ok, "served == client-observed 200s");
    assert_eq!(report.shed, shed as u64, "shed == client-observed 503s");
    assert!(
        (report.mean_batch_occupancy - occ_sum / occ_count).abs() < 1e-9,
        "report mean occupancy equals histogram sum/count"
    );
    assert!(report.energy_mj > 0.0);
    assert!(report.p99_us >= report.p50_us);
}

#[test]
fn predict_rejects_malformed_input() {
    let http = spawn_http(8, 1);
    let addr = http.local_addr();

    let bad_json = http_request(&addr, "POST", "/v1/predict", Some("{not json")).unwrap();
    assert_eq!(bad_json.status, 400);
    assert_envelope(&bad_json.body, 400, "bad_request", false);

    let no_image = http_request(&addr, "POST", "/v1/predict", Some("{}")).unwrap();
    assert_eq!(no_image.status, 400);
    assert_envelope(&no_image.body, 400, "bad_request", false);

    let wrong_shape = http_request(
        &addr,
        "POST",
        "/v1/predict",
        Some("{\"image\":[1,2,3]}"), // 3 values vs 1x28x28
    )
    .unwrap();
    assert_eq!(wrong_shape.status, 400);
    assert!(wrong_shape.body.contains("disagrees"), "{}", wrong_shape.body);
    assert_envelope(&wrong_shape.body, 400, "bad_request", false);

    let lost = http_request(&addr, "GET", "/v1/unknown", None).unwrap();
    assert_eq!(lost.status, 404);
    assert_envelope(&lost.body, 404, "not_found", false);

    // malformed input never ties up an admission slot
    let m = http_request(&addr, "GET", "/metrics", None).unwrap();
    assert_eq!(metric_value(&m.body, "scatter_queue_depth"), 0.0);

    http.shutdown().expect("drain");
}

#[test]
fn expired_deadline_maps_to_504() {
    let http = spawn_http(8, 1);
    let addr = http.local_addr();
    let ds = scatter::data::SyntheticDataset::new(scatter::data::DatasetSpec::fmnist_like());
    let (img, _) = ds.sample(3, 1);
    let body = Json::obj(vec![
        ("image", Json::arr_f64(&img.data)),
        ("deadline_ms", Json::Num(0.0)), // expired on arrival
    ])
    .to_string();
    let resp = http_request(&addr, "POST", "/v1/predict", Some(&body)).unwrap();
    assert_eq!(resp.status, 504, "{}", resp.body);
    assert_envelope(&resp.body, 504, "deadline_exceeded", true);
    let report = http.shutdown().expect("drain");
    assert_eq!(report.expired, 1);
    assert_eq!(report.requests, 0, "expired work never reached an engine");
}

/// A worker that dies with no restart budget leaves the pool degraded:
/// requests keep flowing to the survivor, `/healthz` says so, and the
/// per-worker gauges agree.
#[test]
fn healthz_degrades_when_a_worker_stays_down() {
    let http = spawn_http_cfg(
        ServerConfig::builder()
            .max_batch(8)
            .batch_timeout(Duration::from_millis(1))
            .workers(2)
            .engine_threads(1)
            .faults(FaultPlan::parse("panic@w0:s0", 2).expect("valid spec"))
            .max_restarts(0)
            .build()
            .expect("test config validates"),
    );
    let addr = http.local_addr();
    let body = predict_body();

    // the first shard goes to worker 0 and panics with the shard
    // checkpointed; the supervisor recovers it and (no restart budget)
    // re-dispatches to worker 1 — the client still gets its 200
    let resp = http_request(&addr, "POST", "/v1/predict", Some(&body)).expect("reply");
    assert_eq!(resp.status, 200, "{}", resp.body);

    let health = http_request(&addr, "GET", "/healthz", None).expect("healthz");
    assert_eq!(health.status, 200, "degraded is alive, not down");
    assert!(health.body.contains("\"status\":\"degraded\""), "{}", health.body);
    assert!(health.body.contains("\"workers_live\":1"), "{}", health.body);
    assert!(health.body.contains("\"workers_configured\":2"), "{}", health.body);

    let m = http_request(&addr, "GET", "/metrics", None).expect("metrics");
    assert_eq!(metric_value(&m.body, "scatter_worker_up{worker=\"0\"}"), 0.0);
    assert_eq!(metric_value(&m.body, "scatter_worker_up{worker=\"1\"}"), 1.0);
    assert_eq!(metric_value(&m.body, "scatter_workers_live"), 1.0);
    assert_eq!(
        metric_value(&m.body, "scatter_worker_restarts_total"),
        0.0,
        "max_restarts 0 means the death is permanent"
    );
    assert_eq!(
        metric_value(&m.body, "scatter_request_retries_total"),
        1.0,
        "the recovered request was retried exactly once"
    );

    let report = http.shutdown().expect("drain");
    assert_eq!(report.workers_live, 1);
    assert_eq!(report.worker_restarts, 0);
    assert_eq!(report.requests, 1);
}

/// With the whole pool dead and no restart budget, `/healthz` turns 503
/// and predicts fail fast with a retryable 503 instead of hanging.
#[test]
fn healthz_reports_down_when_no_workers_remain() {
    let http = spawn_http_cfg(
        ServerConfig::builder()
            .max_batch(4)
            .batch_timeout(Duration::from_millis(1))
            .workers(1)
            .engine_threads(1)
            .faults(FaultPlan::parse("panic@w0:s0", 1).expect("valid spec"))
            .max_restarts(0)
            .build()
            .expect("test config validates"),
    );
    let addr = http.local_addr();
    let body = predict_body();

    let resp = http_request(&addr, "POST", "/v1/predict", Some(&body)).expect("reply");
    assert_eq!(resp.status, 503, "only worker dead: retryable, not a hang");
    assert!(resp.retry_after_s.unwrap_or(0) >= 1, "503 carries Retry-After");
    assert_envelope(&resp.body, 503, "unavailable", true);

    let health = http_request(&addr, "GET", "/healthz", None).expect("healthz");
    assert_eq!(health.status, 503, "zero live workers is down, not degraded");
    assert!(health.body.contains("\"status\":\"down\""), "{}", health.body);
    assert!(health.body.contains("\"workers_live\":0"), "{}", health.body);

    let report = http.shutdown().expect("drain");
    assert_eq!(report.workers_live, 0);
    assert!(report.worker_lost >= 1, "the failed request is accounted");
}

/// Drain racing a worker panic conserves replies: clients hammering
/// keep-alive connections while `shutdown()` lands mid-respawn each see
/// exactly one terminal status per request (200 / 503 / 504) — never a
/// hang, never a lost reply — and the server's own served count matches
/// the clients' 200s.
#[test]
fn drain_under_fault_conserves_replies() {
    let http = spawn_http_cfg(
        ServerConfig::builder()
            .max_batch(4)
            .batch_timeout(Duration::from_millis(1))
            .workers(1)
            .engine_threads(1)
            .max_in_flight(64)
            // seq 0 dies under the warm-up request; seq 3 dies somewhere
            // inside the race (or never fires — both are fine)
            .faults(FaultPlan::parse("panic@w0:s0,panic@w0:s3", 1).expect("valid spec"))
            .build()
            .expect("test config validates"),
    );
    let addr = http.local_addr();
    let body = predict_body();

    // warm-up: consumes the seq-0 panic, proving respawn works before
    // the drain race starts
    let resp = http_request(&addr, "POST", "/v1/predict", Some(&body)).expect("reply");
    assert_eq!(resp.status, 200, "{}", resp.body);

    let (oks, others, report) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let body = &body;
                s.spawn(move || {
                    // connect before the drain so the race is over
                    // in-flight work, not over the listener socket
                    let mut client = HttpClient::connect(&addr).expect("connect");
                    let (mut ok, mut other) = (0u64, 0u64);
                    for _ in 0..4 {
                        match client.request("POST", "/v1/predict", Some(body)) {
                            Ok(resp) => match resp.status {
                                200 => ok += 1,
                                503 | 504 => other += 1,
                                s => panic!("unexpected status {s}: {}", resp.body),
                            },
                            // the drain closed this keep-alive
                            // connection after its final response —
                            // nothing accepted, nothing lost
                            Err(_) => break,
                        }
                    }
                    (ok, other)
                })
            })
            .collect();
        let shutdown = s.spawn(|| {
            std::thread::sleep(Duration::from_millis(30));
            http.shutdown().expect("drain under fault")
        });
        let (mut oks, mut others) = (0u64, 0u64);
        for h in handles {
            let (a, b) = h.join().expect("client thread");
            oks += a;
            others += b;
        }
        (oks, others, shutdown.join().expect("shutdown thread"))
    });
    assert_eq!(
        report.requests as u64,
        oks + 1,
        "server-served count equals client-observed 200s (warm-up + {oks} raced, \
         {others} retryable/expired)"
    );
    assert!(report.worker_restarts >= 1, "the seq-0 panic was healed by a respawn");
    assert_eq!(report.workers_live, 1, "the pool is back at full strength");
}

//! End-to-end guarantees of the thermal-drift runtime on the serving
//! stack:
//!
//! * `/metrics` exposes nonzero drift and recalibration gauges while a
//!   drift-enabled deployment serves real TCP traffic;
//! * with the policy off, drift registers but recalibration counters
//!   stay zero (the gauges separate physics from control).
//!
//! Drift schedules here are heat-only with `time_scale: 0`, so every
//! envelope value depends only on each worker's served count — no
//! wall-clock flakiness.

use scatter::config::SparsitySupport;
use scatter::coordinator::net::{http_request, metric_value, HttpClient};
use scatter::coordinator::{
    EngineOptions, HttpServer, InferenceServer, NetConfig, ServerConfig, ThermalServerConfig,
};
use scatter::nn::Tensor;
use scatter::thermal::{DriftConfig, ThermalPolicy};
use scatter::util::Json;
use scatter::AcceleratorConfig;
use std::time::Duration;

fn test_cfg() -> AcceleratorConfig {
    AcceleratorConfig {
        features: SparsitySupport::NONE,
        dac: scatter::config::DacKind::Edac,
        l_g: 5.0,
        ..Default::default()
    }
}

fn heat_only_drift() -> DriftConfig {
    DriftConfig {
        ambient_amp_rad: 0.0,
        self_heat_amp_rad: 0.2,
        self_heat_tau_reqs: 4.0,
        time_scale: 0.0,
        ..DriftConfig::default()
    }
}

fn sample_body() -> String {
    let ds = scatter::data::SyntheticDataset::new(scatter::data::DatasetSpec::fmnist_like());
    let (img, _): (Tensor, usize) = ds.sample(7, 0);
    Json::obj(vec![("image", Json::arr_f64(&img.data))]).to_string()
}

fn spawn_http(policy: ThermalPolicy) -> HttpServer {
    let server = InferenceServer::spawn(
        scatter::nn::models::cnn3(),
        test_cfg(),
        EngineOptions::IDEAL,
        Default::default(),
        ServerConfig::builder()
            .max_batch(2)
            .batch_timeout(Duration::from_millis(1))
            .workers(1)
            .thermal(ThermalServerConfig {
                drift: Some(heat_only_drift()),
                policy,
                ..Default::default()
            })
            .build()
            .expect("drift config validates"),
    );
    HttpServer::bind(server, NetConfig::default()).expect("bind ephemeral port")
}

#[test]
fn metrics_expose_nonzero_drift_and_recalibration_gauges() {
    let http = spawn_http(ThermalPolicy::Threshold { budget_rad: 0.01 });
    let addr = http.local_addr();
    let body = sample_body();
    let mut client = HttpClient::connect(&addr).expect("connect");
    for i in 0..12 {
        let resp = client
            .request("POST", "/v1/predict", Some(&body))
            .unwrap_or_else(|e| panic!("predict {i}: {e}"));
        assert_eq!(resp.status, 200, "predict {i}: {}", resp.body);
    }
    let m = http_request(&addr, "GET", "/metrics", None).expect("metrics");
    assert_eq!(m.status, 200);
    let drift = metric_value(&m.body, "scatter_thermal_drift_rad");
    assert!(drift > 0.0, "self-heating drift must register:\n{}", m.body);
    let recals = metric_value(&m.body, "scatter_thermal_recalibrations_total");
    assert!(recals >= 1.0, "threshold policy must recalibrate:\n{}", m.body);
    let chunks = metric_value(&m.body, "scatter_thermal_recalibrated_chunks_total");
    assert!(chunks >= recals, "each action recompiles ≥ 1 chunk");
    let err = metric_value(&m.body, "scatter_thermal_phase_error_rad");
    assert!(
        err <= 0.01 + 1e-9,
        "threshold policy keeps residual error within budget, got {err}"
    );
    let report = http.shutdown().expect("drain");
    assert_eq!(report.requests, 12);
    // the final shard's tick may land after the scrape, so the report
    // can only ever be ahead of the gauges read mid-flight
    assert!(report.recalibrations as f64 >= recals);
    assert!(report.recal_chunks as f64 >= chunks);
}

#[test]
fn policy_off_registers_drift_but_never_recalibrates() {
    let http = spawn_http(ThermalPolicy::Off);
    let addr = http.local_addr();
    let body = sample_body();
    for _ in 0..8 {
        let resp =
            http_request(&addr, "POST", "/v1/predict", Some(&body)).expect("predict");
        assert_eq!(resp.status, 200);
    }
    let m = http_request(&addr, "GET", "/metrics", None).expect("metrics");
    assert!(metric_value(&m.body, "scatter_thermal_drift_rad") > 0.0);
    assert!(
        metric_value(&m.body, "scatter_thermal_phase_error_rad") > 0.0,
        "uncompensated drift accumulates phase error:\n{}",
        m.body
    );
    assert_eq!(metric_value(&m.body, "scatter_thermal_recalibrations_total"), 0.0);
    let report = http.shutdown().expect("drain");
    assert_eq!(report.recalibrations, 0);
    assert_eq!(report.recal_chunks, 0);
}

//! Execution-layer guarantees of the sparsity-compiled parallel engine:
//!
//! * **determinism** — noisy outputs are bit-identical for any worker
//!   thread count (counter-based per-(chunk, column) noise streams);
//! * **plan correctness** — the compiled active-index path matches the
//!   pre-compilation bool-mask reference path on random structured masks
//!   (dense, row-only, col-only, both) under every gating feature set;
//! * **pass-split invariance** — the two-pass shared-activation-panel
//!   path (`matmul`) is bit-identical to the PR1-style single-pass
//!   uncached path (`matmul_uncached`) for every thread count, feature
//!   set, and odd shape, PD noise included: materializing the quantized
//!   panels in a separate pass must not move a single bit.

use scatter::config::{AcceleratorConfig, SparsitySupport};
use scatter::coordinator::{EngineOptions, PhotonicEngine};
use scatter::exec::{detected_simd, KernelPrecision, SimdLevel};
use scatter::nn::MatmulEngine;
use scatter::sparsity::{ChunkMask, LayerMask};
use scatter::util::{nmae, XorShiftRng};
use std::collections::BTreeMap;

fn problem(out: usize, inp: usize, n_cols: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = XorShiftRng::new(seed);
    let mut w = vec![0.0; out * inp];
    rng.fill_uniform(&mut w, -0.5, 0.5);
    let mut x = vec![0.0; inp * n_cols];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    (w, x)
}

/// Random structured layer mask for a (p × q) grid of (rows × cols)
/// chunks. `kind`: 0 = dense, 1 = row-only, 2 = col-only, 3 = both.
fn random_mask(
    p: usize,
    q: usize,
    rows: usize,
    cols: usize,
    kind: u8,
    rng: &mut XorShiftRng,
) -> LayerMask {
    let mut chunks = Vec::with_capacity(p * q);
    for _ in 0..p * q {
        let row: Vec<bool> = (0..rows)
            .map(|_| kind == 0 || kind == 2 || rng.uniform() < 0.6)
            .collect();
        let col: Vec<bool> = (0..cols)
            .map(|_| kind == 0 || kind == 1 || rng.uniform() < 0.5)
            .collect();
        chunks.push(ChunkMask::new(row, col));
    }
    LayerMask { p, q, chunks }
}

fn engine_with_mask(
    features: SparsitySupport,
    mask: Option<LayerMask>,
    opts: EngineOptions,
) -> PhotonicEngine {
    let cfg = AcceleratorConfig { features, l_g: 5.0, ..Default::default() };
    let mut eng = PhotonicEngine::new(cfg, opts);
    if let Some(m) = mask {
        let mut masks = BTreeMap::new();
        masks.insert("l".to_string(), m);
        eng.set_masks(masks);
    }
    eng
}

#[test]
fn noisy_outputs_bit_identical_across_thread_counts() {
    // full noise stack, structured mask, padded shapes (80 × 96 on a
    // 64 × 64 chunk grid), repeated calls — every thread count must
    // produce the same bits
    let (out, inp, n_cols) = (80, 96, 13);
    let (w, x) = problem(out, inp, n_cols, 1);
    let mut rng = XorShiftRng::new(99);
    let mask = random_mask(2, 2, 64, 64, 3, &mut rng);

    let run = |threads: usize| {
        let mut eng =
            engine_with_mask(SparsitySupport::FULL, Some(mask.clone()), EngineOptions::NOISY);
        eng.set_threads(threads);
        let y1 = eng.matmul("l", &w, &x, out, inp, n_cols);
        let y2 = eng.matmul("l", &w, &x, out, inp, n_cols);
        (y1, y2)
    };
    let (y1_a, y2_a) = run(1);
    for threads in [2, 4, 8] {
        let (y1_b, y2_b) = run(threads);
        assert_eq!(y1_a, y1_b, "first call differs at {threads} threads");
        assert_eq!(y2_a, y2_b, "second call differs at {threads} threads");
    }
    // noise must be redrawn between calls (independent epochs)
    assert_ne!(y1_a, y2_a, "repeated calls should see fresh PD noise");
}

#[test]
fn deterministic_when_noise_off_regardless_of_threads() {
    let (out, inp, n_cols) = (64, 64, 8);
    let (w, x) = problem(out, inp, n_cols, 2);
    let run = |threads: usize| {
        let mut eng = engine_with_mask(SparsitySupport::NONE, None, EngineOptions::IDEAL);
        eng.set_threads(threads);
        eng.matmul("l", &w, &x, out, inp, n_cols)
    };
    let base = run(1);
    assert_eq!(base, run(4));
}

#[test]
fn compiled_plan_matches_reference_path_on_random_masks() {
    // pd noise off so both paths are deterministic; thermal + phase noise
    // on so the realized weights are nontrivial. The same engine serves
    // both paths (programming is cached), so any mismatch is purely the
    // execution layer's fault.
    let opts = EngineOptions { pd_noise: false, ..EngineOptions::NOISY };
    let (out, inp, n_cols) = (80, 96, 5);
    let (w, x) = problem(out, inp, n_cols, 3);
    let mut rng = XorShiftRng::new(7);
    for features in [
        SparsitySupport::NONE,   // ColumnMode::PruneOnly
        SparsitySupport::IG,     // ColumnMode::InputGating (leakage bias)
        SparsitySupport::IG_OG,  // + output gating (row skipping)
        SparsitySupport::FULL,   // ColumnMode::InputGatingLr
    ] {
        for kind in 0..4u8 {
            let mask = random_mask(2, 2, 64, 64, kind, &mut rng);
            let mut eng = engine_with_mask(features, Some(mask), opts);
            let y_plan = eng.matmul("l", &w, &x, out, inp, n_cols);
            let y_ref = eng.matmul_reference("l", &w, &x, out, inp, n_cols);
            let e = nmae(&y_plan, &y_ref);
            assert!(
                e < 1e-9,
                "plan/reference divergence {e} (features {features:?}, mask kind {kind})"
            );
        }
    }
}

#[test]
fn compiled_plan_matches_reference_when_dense_unmasked() {
    let opts = EngineOptions { pd_noise: false, ..EngineOptions::NOISY };
    let (out, inp, n_cols) = (70, 90, 3);
    let (w, x) = problem(out, inp, n_cols, 4);
    let mut eng = engine_with_mask(SparsitySupport::FULL, None, opts);
    let y_plan = eng.matmul("l", &w, &x, out, inp, n_cols);
    let y_ref = eng.matmul_reference("l", &w, &x, out, inp, n_cols);
    assert!(nmae(&y_plan, &y_ref) < 1e-9);
}

#[test]
fn cached_two_pass_bit_identical_to_uncached_single_pass() {
    // PD noise ON: the counter-based per-(chunk, column, epoch) streams
    // must be unaffected by the pass split. Both engines see the same
    // call sequence, so call k draws from epoch k on each — outputs must
    // match bit for bit at every thread count. Mask kind 3 gives every
    // chunk its own random column mask (heterogeneous gather tables
    // across chunk-rows → multiple panel groups per chunk-column); kind
    // 1 keeps columns dense (one shared panel per chunk-column — the
    // maximal-redundancy case the cache removes).
    let (out, inp) = (70, 90);
    for (features, kind) in [
        (SparsitySupport::NONE, 3u8),
        (SparsitySupport::IG, 3),
        (SparsitySupport::IG_OG, 3),
        (SparsitySupport::FULL, 3),
        (SparsitySupport::FULL, 1),
    ] {
        for n_cols in [1usize, 65] {
            let (w, x) = problem(out, inp, n_cols, 7);
            let mut rng = XorShiftRng::new(31 + kind as u64);
            let mask = random_mask(2, 2, 64, 64, kind, &mut rng);
            let mut cached =
                engine_with_mask(features, Some(mask.clone()), EngineOptions::NOISY);
            let mut uncached =
                engine_with_mask(features, Some(mask), EngineOptions::NOISY);
            for threads in [1usize, 2, 4, 8] {
                cached.set_threads(threads);
                uncached.set_threads(threads);
                let y_two_pass = cached.matmul("l", &w, &x, out, inp, n_cols);
                let y_one_pass =
                    uncached.matmul_uncached("l", &w, &x, out, inp, n_cols);
                assert_eq!(
                    y_two_pass, y_one_pass,
                    "pass split moved bits: {features:?} kind {kind} \
                     n_cols {n_cols} threads {threads}"
                );
            }
        }
    }
}

#[test]
fn cached_and_uncached_match_reference_on_odd_shapes() {
    // noise off so all three paths are deterministic; thermal + phase
    // noise on so realized weights are nontrivial. One engine serves all
    // paths (programming is cached), so divergence is purely executional.
    let opts = EngineOptions { pd_noise: false, ..EngineOptions::NOISY };
    let (out, inp) = (70, 90);
    let mut rng = XorShiftRng::new(17);
    for features in [
        SparsitySupport::NONE,
        SparsitySupport::IG,
        SparsitySupport::IG_OG,
        SparsitySupport::FULL,
    ] {
        for n_cols in [1usize, 65] {
            let (w, x) = problem(out, inp, n_cols, 8);
            let mask = random_mask(2, 2, 64, 64, 3, &mut rng);
            let mut eng = engine_with_mask(features, Some(mask), opts);
            eng.set_threads(4);
            let y_plan = eng.matmul("l", &w, &x, out, inp, n_cols);
            let y_un = eng.matmul_uncached("l", &w, &x, out, inp, n_cols);
            let y_ref = eng.matmul_reference("l", &w, &x, out, inp, n_cols);
            assert_eq!(y_plan, y_un, "{features:?} n_cols {n_cols}");
            let e = nmae(&y_plan, &y_ref);
            assert!(e < 1e-9, "plan/reference divergence {e} ({features:?}, {n_cols})");
        }
    }
}

#[test]
fn degenerate_dims_return_empty_without_panicking() {
    // out_dim/in_dim/n_cols of 0 used to reach chunks[0]/blocks[0]
    // indexing (regression: PR 4) — now every path returns the
    // correctly-shaped all-zero product without programming anything
    let mut eng = engine_with_mask(SparsitySupport::FULL, None, EngineOptions::NOISY);
    let x3 = vec![0.5; 16 * 3];
    assert!(eng.matmul("a", &[], &x3, 0, 16, 3).is_empty());
    assert!(eng.matmul_reference("a", &[], &x3, 0, 16, 3).is_empty());
    assert!(eng.matmul_uncached("a", &[], &x3, 0, 16, 3).is_empty());
    assert_eq!(eng.matmul("b", &[], &[], 16, 0, 3), vec![0.0; 48]);
    assert_eq!(eng.matmul_reference("b", &[], &[], 16, 0, 3), vec![0.0; 48]);
    assert_eq!(eng.matmul_uncached("b", &[], &[], 16, 0, 3), vec![0.0; 48]);
    let w = vec![0.25; 16 * 16];
    assert!(eng.matmul("c", &w, &[], 16, 16, 0).is_empty());
    assert!(eng.matmul_reference("c", &w, &[], 16, 16, 0).is_empty());
    assert!(eng.matmul_uncached("c", &w, &[], 16, 16, 0).is_empty());
}

#[test]
fn all_zero_activations_stay_finite_and_equal_across_paths() {
    // all-zero input normalizes against the 1e-12 floor (unsigned-
    // activation contract): outputs must be finite — pure leakage bias
    // under input gating, exact zeros without it — and identical across
    // the three paths
    let opts = EngineOptions { pd_noise: false, ..EngineOptions::NOISY };
    let (out, inp, n_cols) = (70, 90, 5);
    let (w, _) = problem(out, inp, n_cols, 9);
    let x = vec![0.0; inp * n_cols];
    let mut rng = XorShiftRng::new(23);
    for features in [SparsitySupport::NONE, SparsitySupport::IG, SparsitySupport::FULL] {
        let mask = random_mask(2, 2, 64, 64, 3, &mut rng);
        let mut eng = engine_with_mask(features, Some(mask), opts);
        let y = eng.matmul("l", &w, &x, out, inp, n_cols);
        assert!(y.iter().all(|v| v.is_finite()), "{features:?}: non-finite output");
        let y_un = eng.matmul_uncached("l", &w, &x, out, inp, n_cols);
        let y_ref = eng.matmul_reference("l", &w, &x, out, inp, n_cols);
        assert_eq!(y, y_un, "{features:?}");
        assert!(nmae(&y, &y_ref) < 1e-9, "{features:?}");
    }
}

/// A standalone `matmul_batch` (no `begin_batch` context) must be
/// bit-identical to the `batch` sequential `matmul` calls it replaces:
/// item `g` draws the epoch `g` prior plain calls would have consumed,
/// normalizes against its own activation maximum, and addresses noise
/// by item-local column — for every thread count, with the full noise
/// stack on.
#[test]
fn standalone_batched_matmul_equals_sequential_item_calls() {
    let (out, inp) = (70, 90);
    let mut mrng = XorShiftRng::new(77);
    let mask = random_mask(2, 2, 64, 64, 3, &mut mrng);
    let (w, _) = problem(out, inp, 1, 5);
    for (cpi, batch) in [(1usize, 5usize), (13, 2), (13, 5)] {
        let n_cols = cpi * batch;
        let mut rng = XorShiftRng::new(55 + cpi as u64);
        let items: Vec<Vec<f64>> = (0..batch)
            .map(|_| {
                let mut v = vec![0.0; inp * cpi];
                rng.fill_uniform(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        // pack the items column-wise (item-major columns)
        let mut packed = vec![0.0; inp * n_cols];
        for (g, item) in items.iter().enumerate() {
            for j in 0..inp {
                packed[j * n_cols + g * cpi..j * n_cols + (g + 1) * cpi]
                    .copy_from_slice(&item[j * cpi..(j + 1) * cpi]);
            }
        }
        for threads in [1usize, 4] {
            let mut e_seq = engine_with_mask(
                SparsitySupport::FULL,
                Some(mask.clone()),
                EngineOptions::NOISY,
            );
            let mut e_bat = engine_with_mask(
                SparsitySupport::FULL,
                Some(mask.clone()),
                EngineOptions::NOISY,
            );
            e_seq.set_threads(threads);
            e_bat.set_threads(threads);
            let y_bat = e_bat.matmul_batch("l", &w, &packed, out, inp, cpi, batch);
            for (g, item) in items.iter().enumerate() {
                let y_g = e_seq.matmul("l", &w, item, out, inp, cpi);
                for o in 0..out {
                    for t in 0..cpi {
                        assert_eq!(
                            y_bat[o * n_cols + g * cpi + t],
                            y_g[o * cpi + t],
                            "cpi {cpi} batch {batch} threads {threads} item {g} \
                             row {o} col {t}"
                        );
                    }
                }
            }
            // both engines must leave the epoch at the same place
            let probe = &items[0];
            assert_eq!(
                e_bat.matmul("l", &w, probe, out, inp, cpi),
                e_seq.matmul("l", &w, probe, out, inp, cpi),
                "post-call epoch diverged (cpi {cpi} batch {batch})"
            );
        }
    }
}

/// Documents that the batched column-offset convention is load-bearing:
/// a *flat* call over the same packed panel shares item 0's noise
/// streams (epoch base, columns 0..cpi) but addresses every later
/// item's columns globally — so item 0 agrees bit-for-bit and the rest
/// diverge. Items are identical copies, which pins the activation
/// maximum (and therefore quantization) equal across both calls; any
/// difference is purely noise addressing.
#[test]
fn batched_noise_addressing_differs_from_flat_call_after_item_zero() {
    let (out, inp, cpi, batch) = (64, 64, 7, 3);
    let n_cols = cpi * batch;
    let (w, item) = problem(out, inp, cpi, 6);
    let mut packed = vec![0.0; inp * n_cols];
    for g in 0..batch {
        for j in 0..inp {
            packed[j * n_cols + g * cpi..j * n_cols + (g + 1) * cpi]
                .copy_from_slice(&item[j * cpi..(j + 1) * cpi]);
        }
    }
    let mut e_flat = engine_with_mask(SparsitySupport::FULL, None, EngineOptions::NOISY);
    let mut e_bat = engine_with_mask(SparsitySupport::FULL, None, EngineOptions::NOISY);
    let y_flat = e_flat.matmul("l", &w, &packed, out, inp, n_cols);
    let y_bat = e_bat.matmul_batch("l", &w, &packed, out, inp, cpi, batch);
    let item_cols = |y: &[f64], g: usize| -> Vec<f64> {
        let mut v = Vec::with_capacity(out * cpi);
        for o in 0..out {
            v.extend_from_slice(&y[o * n_cols + g * cpi..o * n_cols + (g + 1) * cpi]);
        }
        v
    };
    assert_eq!(
        item_cols(&y_flat, 0),
        item_cols(&y_bat, 0),
        "item 0 shares (epoch, chunk, 0..cpi) streams in both addressings"
    );
    assert_ne!(
        item_cols(&y_flat, 1),
        item_cols(&y_bat, 1),
        "later items must re-key noise per item — flat addressing would \
         correlate a batch's noise with its packing order"
    );
}

/// The quantized kernel's core property: the SIMD sweep and the scalar
/// integer oracle see the same i16 inputs and must therefore produce
/// the same i32 sums — so engine outputs are bit-identical between the
/// detected SIMD variant and a forced-scalar override, across every
/// thread count, mask feature set, and ragged shape, full noise stack
/// on. (On hosts without AVX2 both engines run scalar and the assert
/// pins that the override plumbing itself moves no bits.)
#[test]
fn quantized_simd_equals_forced_scalar_across_threads_masks_shapes() {
    let mut rng = XorShiftRng::new(41);
    for (features, kind) in [
        (SparsitySupport::NONE, 3u8),
        (SparsitySupport::IG, 3),
        (SparsitySupport::IG_OG, 3),
        (SparsitySupport::FULL, 3),
    ] {
        for (out, inp, n_cols) in [(70usize, 90usize, 5usize), (33, 50, 65)] {
            let (w, x) = problem(out, inp, n_cols, 13);
            let mask = random_mask(2, 2, 64, 64, kind, &mut rng);
            let mut simd =
                engine_with_mask(features, Some(mask.clone()), EngineOptions::NOISY);
            let mut scalar =
                engine_with_mask(features, Some(mask), EngineOptions::NOISY);
            simd.set_precision(KernelPrecision::Quantized);
            scalar.set_precision(KernelPrecision::Quantized);
            scalar.set_simd_override(Some(SimdLevel::Scalar));
            assert_eq!(scalar.simd_level(), SimdLevel::Scalar);
            for threads in [1usize, 2, 4, 8] {
                simd.set_threads(threads);
                scalar.set_threads(threads);
                assert_eq!(
                    simd.matmul("l", &w, &x, out, inp, n_cols),
                    scalar.matmul("l", &w, &x, out, inp, n_cols),
                    "simd != scalar: {features:?} kind {kind} \
                     {out}x{inp}x{n_cols} threads {threads}"
                );
            }
        }
    }
}

/// The forced-scalar override: clamped to detection, restorable, and
/// the engine defaults to the bit-exact mode.
#[test]
fn simd_override_clamps_to_detection_and_default_is_exact() {
    let mut eng = engine_with_mask(SparsitySupport::FULL, None, EngineOptions::NOISY);
    assert_eq!(eng.precision(), KernelPrecision::Exact, "Exact is the default");
    let detected = detected_simd();
    assert_eq!(eng.simd_level(), detected);
    // requesting more than the host supports clamps to detection
    eng.set_simd_override(Some(SimdLevel::Avx512));
    assert!(eng.simd_level() <= detected);
    eng.set_simd_override(Some(SimdLevel::Scalar));
    assert_eq!(eng.simd_level(), SimdLevel::Scalar);
    eng.set_simd_override(None);
    assert_eq!(eng.simd_level(), detected);
}

/// Quantized mode keeps every determinism invariant (thread counts,
/// repeated-call noise epochs) while changing rounding: outputs are
/// bit-stable per thread count but differ from Exact.
#[test]
fn quantized_outputs_deterministic_and_distinct_from_exact() {
    let (out, inp, n_cols) = (80, 96, 13);
    let (w, x) = problem(out, inp, n_cols, 14);
    let mut rng = XorShiftRng::new(43);
    let mask = random_mask(2, 2, 64, 64, 3, &mut rng);
    let run = |threads: usize, precision: KernelPrecision| {
        let mut eng =
            engine_with_mask(SparsitySupport::FULL, Some(mask.clone()), EngineOptions::NOISY);
        eng.set_precision(precision);
        eng.set_threads(threads);
        eng.matmul("l", &w, &x, out, inp, n_cols)
    };
    let q1 = run(1, KernelPrecision::Quantized);
    for threads in [2, 4, 8] {
        assert_eq!(
            q1,
            run(threads, KernelPrecision::Quantized),
            "quantized output moved at {threads} threads"
        );
    }
    let exact = run(1, KernelPrecision::Exact);
    assert_ne!(q1, exact, "integer accumulation must change rounding");
    // and stays numerically close to the exact kernel
    let e = nmae(&q1, &exact);
    assert!(e < 0.02, "quantized drifted {e} from exact");
}

/// The ISSUE 10 accuracy gate: on a class-structured eval set (clear
/// readout margins, like a trained model's), the Quantized engine's
/// per-column argmax must agree with Exact on >= 99% of columns. Both
/// engines draw identical counter-based noise (same seed, same epoch
/// sequence), so any disagreement is purely integer rounding.
#[test]
fn quantized_argmax_agreement_with_exact_is_at_least_99_percent() {
    let (classes, dim, n_eval) = (10usize, 64usize, 300usize);
    let mut rng = XorShiftRng::new(61);
    // class prototypes in activation space; readout row c = prototype c
    let mut protos = vec![0.0f64; classes * dim];
    rng.fill_uniform(&mut protos, 0.0, 1.0);
    let w = protos.clone();
    // eval columns: a prototype blended with noise (margin >> quant error)
    let mut x = vec![0.0f64; dim * n_eval];
    let mut labels = Vec::with_capacity(n_eval);
    for t in 0..n_eval {
        let c = (rng.uniform() * classes as f64) as usize % classes;
        labels.push(c);
        for j in 0..dim {
            let noise = rng.uniform() * 0.3;
            x[j * n_eval + t] = 0.7 * protos[c * dim + j] + noise;
        }
    }
    let argmax_cols = |y: &[f64]| -> Vec<usize> {
        (0..n_eval)
            .map(|t| {
                (0..classes)
                    .map(|o| (o, y[o * n_eval + t]))
                    .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect()
    };
    let run = |precision: KernelPrecision| {
        let mut eng = engine_with_mask(SparsitySupport::FULL, None, EngineOptions::NOISY);
        eng.set_precision(precision);
        eng.set_threads(4);
        eng.matmul("readout", &w, &x, classes, dim, n_eval)
    };
    let exact = argmax_cols(&run(KernelPrecision::Exact));
    let quant = argmax_cols(&run(KernelPrecision::Quantized));
    let agree = exact.iter().zip(&quant).filter(|(a, b)| a == b).count();
    let rate = agree as f64 / n_eval as f64;
    assert!(
        rate >= 0.99,
        "argmax agreement {rate} < 0.99 ({agree}/{n_eval} columns)"
    );
}

#[test]
fn noise_statistics_survive_compilation() {
    // the planned path draws noise from per-(chunk, column) streams
    // instead of one sequential RNG; the per-output std must stay
    // σ·√(c·k2): default config c=4, k2=16 → √64·0.01 = 0.08 before
    // LR rescale (dense layer ⇒ lr_gain = 1)
    let opts = EngineOptions {
        thermal: false,
        phase_noise: false,
        pd_noise: true,
        quantize: false,
    };
    let (out, inp) = (64, 64);
    let (w, x) = problem(out, inp, 1, 5);
    let mut eng = engine_with_mask(SparsitySupport::NONE, None, opts);
    let golden = {
        let mut ideal = engine_with_mask(SparsitySupport::NONE, None, EngineOptions {
            pd_noise: false,
            ..opts
        });
        ideal.matmul("l", &w, &x, out, inp, 1)
    };
    let mut acc2 = 0.0;
    let trials = 3000;
    let mut scale_probe = 0.0f64;
    for v in &w {
        scale_probe = scale_probe.max(v.abs());
    }
    let x_max = x.iter().fold(0.0f64, |m, &v| m.max(v));
    for _ in 0..trials {
        let y = eng.matmul("l", &w, &x, out, inp, 1);
        for i in 0..out {
            acc2 += (y[i] - golden[i]).powi(2);
        }
    }
    let std = (acc2 / (trials * out) as f64).sqrt() / (scale_probe * x_max);
    assert!((std - 0.08).abs() < 0.005, "per-output noise std {std} (want ≈0.08)");
}

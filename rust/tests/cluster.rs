//! Cluster-scheduler integration tests (the PR's acceptance scenario):
//! thermal-aware routing steers load off a forced-hot replica without
//! dropping aggregate service, and work stealing drains a stalled
//! replica's backlog through an idle peer.
//!
//! Drift schedules are heat-only with `time_scale: 0`, so the heat
//! envelope depends only on each worker's served count — deterministic
//! up to dispatch/tick interleaving, which the assertions leave slack
//! for (the hot replica may serve a request or two before its first
//! thermal tick publishes the brownout).

use scatter::config::{AcceleratorConfig, DacKind, SparsitySupport};
use scatter::coordinator::{
    EngineOptions, FaultPlan, InferenceServer, ServerConfig, ThermalServerConfig,
};
use scatter::nn::Tensor;
use scatter::thermal::{DriftConfig, ThermalPolicy};
use std::time::Duration;

fn test_cfg() -> AcceleratorConfig {
    AcceleratorConfig {
        features: SparsitySupport::NONE,
        dac: DacKind::Edac,
        l_g: 5.0,
        ..Default::default()
    }
}

fn sample_img() -> Tensor {
    let ds = scatter::data::SyntheticDataset::new(scatter::data::DatasetSpec::fmnist_like());
    ds.sample(11, 0).0
}

/// Self-heating-only drift: one request pushes phase error to
/// ~44 mrad, far past the 1 mrad brownout budget used below.
fn heat_only_drift() -> DriftConfig {
    DriftConfig {
        ambient_amp_rad: 0.0,
        self_heat_amp_rad: 0.2,
        self_heat_tau_reqs: 4.0,
        time_scale: 0.0,
        ..DriftConfig::default()
    }
}

/// Acceptance criterion: with 4 replicas and drift injected on exactly
/// one (`drift_only_worker`), the router steers load off the hot
/// replica — its routed share collapses — while every request is still
/// served (aggregate service intact; the pool absorbs the brownout).
#[test]
fn router_steers_load_off_a_hot_replica() {
    const WORKERS: usize = 4;
    const REQUESTS: usize = 16;
    let server = InferenceServer::spawn(
        scatter::nn::models::cnn3(),
        test_cfg(),
        EngineOptions::IDEAL,
        Default::default(),
        ServerConfig::builder()
            .max_batch(1) // every request is one shard: routing is the only lever
            .batch_timeout(Duration::from_millis(1))
            .workers(WORKERS)
            .thermal(ThermalServerConfig {
                drift: Some(heat_only_drift()),
                policy: ThermalPolicy::Off,
                brownout_budget_rad: Some(1e-3),
                drift_only_worker: Some(0),
            })
            .build()
            .expect("cluster config validates"),
    );

    // closed loop: each reply lands before the next submit, so the
    // router sees worker 0's post-tick heat/brownout state almost
    // immediately after its first served shard
    let img = sample_img();
    for i in 0..REQUESTS {
        let rx = server.submit(img.clone()).expect("admitted");
        let reply = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("reply")
            .unwrap_or_else(|e| panic!("request {i} failed: {e:?}"));
        assert_eq!(reply.logits.len(), 10, "request {i} served a real prediction");
    }

    let snap = server.snapshot();
    assert_eq!(snap.replica_heat_milli.len(), WORKERS);
    assert!(
        snap.replica_heat_milli[0] >= 1,
        "the drift-injected replica publishes a nonzero heat score: {:?}",
        snap.replica_heat_milli
    );

    let report = server.shutdown().expect("drain");
    assert_eq!(report.requests, REQUESTS, "aggregate service intact under brownout");
    assert_eq!(report.routed.len(), WORKERS);
    assert_eq!(
        report.routed.iter().sum::<u64>(),
        REQUESTS as u64,
        "every shard routed exactly once: {:?}",
        report.routed
    );
    assert!(
        report.routed[0] <= 3,
        "hot replica's routed load collapses (tick-lag slack of 3): {:?}",
        report.routed
    );
    assert!(
        report.routed[1..].iter().sum::<u64>() >= (REQUESTS as u64) - 3,
        "cool replicas absorb the load: {:?}",
        report.routed
    );
    assert!(report.brownouts >= 1, "the forced-hot replica tripped its budget");
    assert_eq!(report.workers_live, WORKERS, "brownout degrades, never kills");
}

/// An injected slow shard pins replica 0 while its queued shard is
/// stolen and served by the idle peer — the backlog never waits out the
/// stall.
#[test]
fn idle_replica_steals_backlog_from_a_stalled_peer() {
    const REQUESTS: usize = 8;
    let server = InferenceServer::spawn(
        scatter::nn::models::cnn3(),
        test_cfg(),
        EngineOptions::IDEAL,
        Default::default(),
        ServerConfig::builder()
            .max_batch(1)
            .batch_timeout(Duration::from_millis(1))
            .workers(2)
            .steal(true)
            // worker 0's first two shards reply ~300 ms late; stolen
            // shards execute under the thief's identity, so a steal
            // also dodges the fault — exactly the latency win stealing
            // is for
            .faults(
                FaultPlan::parse("slow@w0:s0:300ms,slow@w0:s1:300ms", 2).expect("spec"),
            )
            .build()
            .expect("steal config validates"),
    );

    // burst-submit so shards queue behind worker 0's stall, then
    // collect: every reply must arrive
    let img = sample_img();
    let rxs: Vec<_> =
        (0..REQUESTS).map(|_| server.submit(img.clone()).expect("admitted")).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("reply")
            .unwrap_or_else(|e| panic!("request {i} failed: {e:?}"));
        assert_eq!(reply.logits.len(), 10);
    }

    let report = server.shutdown().expect("drain");
    assert_eq!(report.requests, REQUESTS, "stealing loses nothing");
    assert!(
        report.steals >= 1,
        "the idle replica must steal from the stalled one: {report:?}"
    );
    assert_eq!(report.routed.len(), 2);
    assert_eq!(report.routed.iter().sum::<u64>(), REQUESTS as u64);
}

/// The builder is the public construction path; invalid shapes must be
/// typed config errors at build time, not panics at spawn time.
#[test]
fn builder_validation_is_enforced_at_the_public_api() {
    assert!(ServerConfig::builder().workers(0).build().is_err());
    assert!(ServerConfig::builder().max_batch(0).build().is_err());
    let err = ServerConfig::builder()
        .batch_timeout(Duration::from_millis(50))
        .watchdog(Duration::from_millis(10))
        .build()
        .unwrap_err();
    assert!(
        err.to_string().contains("watchdog"),
        "watchdog/batch_timeout invariant names the fields: {err}"
    );
    // and the happy path round-trips through JSON for --config files
    let cfg = ServerConfig::builder()
        .workers(3)
        .steal(true)
        .build()
        .expect("valid config");
    let back = ServerConfig::from_json(&cfg.to_json().to_string()).expect("roundtrip");
    assert_eq!(back.workers(), 3);
    assert!(back.steal());
}

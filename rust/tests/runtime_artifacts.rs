//! Cross-layer integration: the AOT artifacts (python/jax/pallas → HLO
//! text) executed through the rust PJRT runtime must match both the jax
//! oracle math and the rust digital-twin physics.
//!
//! These tests are skipped (with a notice) when `make artifacts` hasn't
//! run — the rest of the suite stays self-contained.

use scatter::runtime::ArtifactRuntime;
use scatter::thermal::{coupling::ArrayGeometry, CouplingModel, GammaModel};
use scatter::util::XorShiftRng;

const K: usize = 16;
const BATCH: usize = 32;

fn runtime_or_skip() -> Option<ArtifactRuntime> {
    let rt = match ArtifactRuntime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP: PJRT runtime unavailable ({e})");
            return None;
        }
    };
    if rt.has_artifact("ptc16_noisy") && rt.has_artifact("ptc16_ideal") {
        Some(rt)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn coupling_f32() -> (Vec<f32>, Vec<f32>) {
    // identical geometry to the python AOT lowering: l_v=120, l_h=16, l_s=9
    let geom = ArrayGeometry { rows: K, cols: K, l_v: 120.0, l_h: 16.0, l_s: 9.0 };
    let cm = CouplingModel::new(geom, &GammaModel::paper());
    let (p, n) = cm.matrices();
    (p.iter().map(|&v| v as f32).collect(), n.iter().map(|&v| v as f32).collect())
}

#[test]
fn ideal_artifact_matches_exact_mvm() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = XorShiftRng::new(1);
    let mut w = vec![0f32; K * K];
    for v in w.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0) as f32;
    }
    let rm: Vec<f32> = (0..K).map(|i| (i % 4 != 3) as u8 as f32).collect();
    let cm: Vec<f32> = (0..K).map(|j| (j % 2 == 0) as u8 as f32).collect();
    let mut x = vec![0f32; BATCH * K];
    for v in x.iter_mut() {
        *v = rng.uniform_in(0.0, 1.0) as f32;
    }
    let y = rt
        .run_f32("ptc16_ideal", &[(&w, &[K, K]), (&rm, &[K]), (&cm, &[K]), (&x, &[BATCH, K])])
        .expect("execute ideal artifact");
    assert_eq!(y.len(), BATCH * K);
    // compare to exact masked MVM
    for b in 0..BATCH {
        for i in 0..K {
            let mut acc = 0f32;
            for j in 0..K {
                acc += w[i * K + j] * rm[i] * cm[j] * x[b * K + j];
            }
            let got = y[b * K + i];
            assert!(
                (got - acc).abs() < 1e-4,
                "batch {b} out {i}: artifact {got} vs exact {acc}"
            );
        }
    }
}

#[test]
fn noisy_artifact_matches_rust_twin_physics() {
    // With zero noise draws, the artifact computes: crosstalk-perturbed
    // weights + IG+LR + OG — exactly the rust ProgrammedPtc with
    // phase_noise/pd_noise off. The coupling matrices come from the SAME
    // Eq. 9/10 constants on both sides, so outputs must agree to f32.
    let Some(mut rt) = runtime_or_skip() else { return };
    let (gp, gn) = coupling_f32();
    let mut rng = XorShiftRng::new(2);
    let mut w = vec![0f32; K * K];
    for v in w.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0) as f32;
    }
    let rm: Vec<f32> = (0..K).map(|i| (i % 3 != 2) as u8 as f32).collect();
    let cmask: Vec<f32> = (0..K).map(|j| (j % 2 == 0) as u8 as f32).collect();
    let mut x = vec![0f32; BATCH * K];
    for v in x.iter_mut() {
        *v = rng.uniform_in(0.0, 1.0) as f32;
    }
    let noise = vec![0f32; BATCH * K];
    let y = rt
        .run_f32(
            "ptc16_noisy",
            &[
                (&w, &[K, K]),
                (&gp, &[K * K, K * K]),
                (&gn, &[K * K, K * K]),
                (&rm, &[K]),
                (&cmask, &[K]),
                (&x, &[BATCH, K]),
                (&noise, &[BATCH, K]),
            ],
        )
        .expect("execute noisy artifact");

    // rust twin with identical geometry + masks, noise off
    use scatter::devices::DeviceLibrary;
    use scatter::ptc::crossbar::{ColumnMode, ForwardOptions, PtcSimulator};
    let geom = ArrayGeometry { rows: K, cols: K, l_v: 120.0, l_h: 16.0, l_s: 9.0 };
    let sim = PtcSimulator::new(geom, &GammaModel::paper(), DeviceLibrary::default());
    let w64: Vec<f64> = w.iter().map(|&v| v as f64).collect();
    let rm_b: Vec<bool> = rm.iter().map(|&v| v > 0.5).collect();
    let cm_b: Vec<bool> = cmask.iter().map(|&v| v > 0.5).collect();
    let opts = ForwardOptions {
        thermal: true,
        col_mask: Some(&cm_b),
        row_mask: Some(&rm_b),
        col_mode: ColumnMode::InputGatingLr,
        output_gating: true,
        ..Default::default()
    };
    let mut max_err = 0f64;
    for b in 0..BATCH {
        let xb: Vec<f64> = (0..K).map(|j| x[b * K + j] as f64).collect();
        let y_rust = sim.forward(&w64, &xb, &opts, &mut XorShiftRng::new(0));
        for i in 0..K {
            max_err = max_err.max((y[b * K + i] as f64 - y_rust[i]).abs());
        }
    }
    assert!(
        max_err < 5e-4,
        "python-pallas artifact and rust twin diverge: max err {max_err}"
    );
    println!("artifact vs rust twin max abs err: {max_err:.2e}");
}

#[test]
fn artifact_compile_is_cached() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let t0 = std::time::Instant::now();
    rt.load("ptc16_ideal").unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    rt.load("ptc16_ideal").unwrap();
    let second = t1.elapsed();
    assert!(second < first / 2, "second load should hit the cache: {first:?} vs {second:?}");
}

//! Batched-vs-sequential value identity of the batch-compute pipeline
//! (the ISSUE 5 tentpole property): `Model::forward_batch` over B
//! images must be **bit-identical** to B sequential `Model::forward`
//! calls on an engine with the same starting state — across batch sizes
//! {1, 2, 5, 8}, engine thread counts {1, 4}, masked/dense layers, and
//! PD noise on/off.
//!
//! The column-offset convention that makes the noisy case hold: a
//! batched matmul's columns are item-major (`cols_per_item` per image),
//! and item `g`'s column `t` draws PD noise from the counter-based
//! stream `(epoch(g), chunk, t)` where `epoch(g) = base +
//! g·matmuls_per_item + call_index` — exactly the epoch the sequential
//! schedule would have consumed (`MatmulEngine::begin_batch` declares
//! the geometry). Normalization is likewise per item: each image
//! quantizes against its own activation maximum, never a batch-wide
//! one. The post-batch test asserts the epoch counter also *lands*
//! where the sequential schedule leaves it, so traffic after a batch
//! draws identical noise too.

use scatter::config::{AcceleratorConfig, DacKind, SparsitySupport};
use scatter::coordinator::{EngineOptions, PhotonicEngine};
use scatter::nn::{Layer, Model, Tensor};
use scatter::sparsity::LayerMask;
use std::collections::BTreeMap;

fn acc_cfg(features: SparsitySupport) -> AcceleratorConfig {
    AcceleratorConfig { features, dac: DacKind::Edac, l_g: 5.0, ..Default::default() }
}

fn engine(
    features: SparsitySupport,
    opts: EngineOptions,
    masks: &BTreeMap<String, LayerMask>,
    threads: usize,
) -> PhotonicEngine {
    let mut eng = PhotonicEngine::new(acc_cfg(features), opts);
    eng.set_masks(masks.clone());
    eng.set_threads(threads);
    eng
}

fn images(n: usize, seed: u64) -> Vec<Tensor> {
    let ds = scatter::data::SyntheticDataset::new(scatter::data::DatasetSpec::fmnist_like());
    (0..n).map(|i| ds.sample(seed.wrapping_add(i as u64) % 10, i).0).collect()
}

/// Run the property for one (model, masks) pair over the full
/// {B} × {threads} × {noise on/off} matrix.
fn assert_batched_equals_sequential(
    model: &Model,
    masks: &BTreeMap<String, LayerMask>,
    batches: &[usize],
    label: &str,
) {
    let features = SparsitySupport::FULL;
    for opts in [EngineOptions::IDEAL, EngineOptions::NOISY] {
        for threads in [1usize, 4] {
            for &b in batches {
                let mut seq = engine(features, opts, masks, threads);
                let mut bat = engine(features, opts, masks, threads);
                let imgs = images(b, 7);
                let y_seq: Vec<Tensor> =
                    imgs.iter().map(|im| model.forward(im.clone(), &mut seq)).collect();
                let y_bat = model.forward_batch(imgs, &mut bat);
                assert_eq!(y_bat.len(), b);
                for (g, (yb, ys)) in y_bat.iter().zip(&y_seq).enumerate() {
                    assert_eq!(
                        yb, ys,
                        "{label}: batched != sequential (pd_noise {}, threads \
                         {threads}, B {b}, item {g})",
                        opts.pd_noise
                    );
                }
                // the batch must leave the noise epoch exactly where B
                // sequential forwards do: the next request on each
                // engine draws the same bits
                let after = images(1, 99).pop().unwrap();
                let y_after_seq = model.forward(after.clone(), &mut seq);
                let y_after_bat = model.forward(after, &mut bat);
                assert_eq!(
                    y_after_seq, y_after_bat,
                    "{label}: post-batch epoch diverged (pd_noise {}, threads \
                     {threads}, B {b})",
                    opts.pd_noise
                );
            }
        }
    }
}

/// The full ISSUE-5 matrix on the FC workload (every matmul layer
/// carries one column per image — the batching-sensitive shape).
#[test]
fn mlp_forward_batch_matches_sequential_dense_and_masked() {
    let model = scatter::nn::models::mlp();
    let dense = BTreeMap::new();
    assert_batched_equals_sequential(&model, &dense, &[1, 2, 5, 8], "mlp dense");
    let masked =
        scatter::bench::common::build_masks(&model, &acc_cfg(SparsitySupport::FULL), 0.3);
    assert!(!masked.is_empty(), "mlp must have a maskable middle layer");
    assert_batched_equals_sequential(&model, &masked, &[1, 2, 5, 8], "mlp masked");
}

/// The conv workload (im2col lowering: many columns per image) on the
/// served CNN-3 model, masked like the serving deployment.
#[test]
fn cnn3_forward_batch_matches_sequential() {
    let model = scatter::nn::models::cnn3();
    let masked =
        scatter::bench::common::build_masks(&model, &acc_cfg(SparsitySupport::FULL), 0.3);
    assert_batched_equals_sequential(&model, &masked, &[1, 3], "cnn3 masked");
}

/// Degenerate (zero-dim) matmul layers return early without consuming a
/// noise epoch in sequential execution; `matmul_layer_count` must
/// exclude them from the batched stride or every later item's streams
/// (and all post-batch traffic) would shift.
#[test]
fn degenerate_matmul_layer_keeps_epoch_contract() {
    let mut rng = scatter::util::XorShiftRng::new(0xDE6);
    let mut w = vec![0.0; 8 * 784];
    rng.fill_uniform(&mut w, -0.3, 0.3);
    let model = Model {
        name: "degen".into(),
        input_shape: vec![1, 28, 28],
        layers: vec![
            Layer::Flatten,
            Layer::Linear {
                name: "fc".into(),
                out_dim: 8,
                in_dim: 784,
                weight: w,
                bias: vec![0.0; 8],
            },
            Layer::Linear {
                name: "dead".into(),
                out_dim: 0,
                in_dim: 8,
                weight: Vec::new(),
                bias: Vec::new(),
            },
        ],
    };
    assert_eq!(model.matmul_layer_count(), 1, "degenerate layer consumes no epoch");
    assert_eq!(model.matmul_layers().len(), 2, "but still lists for masks/protection");
    // a zero-dim tail makes every output empty, so the contract is only
    // observable through the epoch counter: run batched vs sequential,
    // then probe both engines with a *different* noisy model — if the
    // degenerate layer had shifted the stride, the probes would draw
    // different noise bits
    let probe_model = scatter::nn::models::mlp();
    for threads in [1usize, 4] {
        let empty = BTreeMap::new();
        let mut seq = engine(SparsitySupport::FULL, EngineOptions::NOISY, &empty, threads);
        let mut bat = engine(SparsitySupport::FULL, EngineOptions::NOISY, &empty, threads);
        let imgs = images(3, 7);
        let y_seq: Vec<Tensor> =
            imgs.iter().map(|im| model.forward(im.clone(), &mut seq)).collect();
        let y_bat = model.forward_batch(imgs, &mut bat);
        for (yb, ys) in y_bat.iter().zip(&y_seq) {
            assert_eq!(yb, ys, "empty outputs must still agree in shape");
        }
        let after = images(1, 99).pop().unwrap();
        assert_eq!(
            probe_model.forward(after.clone(), &mut seq),
            probe_model.forward(after, &mut bat),
            "degenerate layer shifted the noise epoch (threads {threads})"
        );
    }
}

/// Residual blocks (body + shortcut both batched) through the photonic
/// engine on a small custom model — resnet18 itself is too heavy for a
/// bit-identity sweep.
#[test]
fn residual_conv_model_forward_batch_matches_sequential() {
    let mut rng = scatter::util::XorShiftRng::new(0x5E5);
    let mut w1 = vec![0.0; 4 * 1 * 9];
    rng.fill_uniform(&mut w1, -0.5, 0.5);
    let mut wr = vec![0.0; 4 * 4 * 9];
    rng.fill_uniform(&mut wr, -0.5, 0.5);
    let mut wd = vec![0.0; 4 * 4];
    rng.fill_uniform(&mut wd, -0.5, 0.5);
    let mut wl = vec![0.0; 10 * 4 * 49];
    rng.fill_uniform(&mut wl, -0.3, 0.3);
    let model = Model {
        name: "mini-res".into(),
        input_shape: vec![1, 28, 28],
        layers: vec![
            Layer::Conv2d {
                name: "stem".into(),
                out_c: 4,
                in_c: 1,
                k: 3,
                stride: 2,
                pad: 1,
                weight: w1,
                bias: vec![0.05; 4],
            },
            Layer::Relu,
            Layer::Residual {
                body: vec![Layer::Conv2d {
                    name: "res.conv".into(),
                    out_c: 4,
                    in_c: 4,
                    k: 3,
                    stride: 1,
                    pad: 1,
                    weight: wr,
                    bias: vec![0.0; 4],
                }],
                shortcut: vec![Layer::Conv2d {
                    name: "res.down".into(),
                    out_c: 4,
                    in_c: 4,
                    k: 1,
                    stride: 1,
                    pad: 0,
                    weight: wd,
                    bias: vec![0.0; 4],
                }],
            },
            Layer::MaxPool { k: 2 },
            Layer::Flatten,
            Layer::Linear {
                name: "head".into(),
                out_dim: 10,
                in_dim: 4 * 49,
                weight: wl,
                bias: vec![0.0; 10],
            },
        ],
    };
    assert_batched_equals_sequential(&model, &BTreeMap::new(), &[1, 4], "mini-res");
}

//! Sparsity-machinery benchmarks: rerouter programming, mask power
//! metric, power-optimal combination search (the Alg.-1 inner loops).

use scatter::bench::timing::bench;
use scatter::devices::{Mzi, MziSpec};
use scatter::rerouter::RerouterTree;
use scatter::sparsity::{best_segment_mask, init_layer_mask, mask_power_mw};
use scatter::thermal::GammaModel;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(300);
    let gamma = GammaModel::paper();
    let mzi = Mzi::new(MziSpec::low_power(), 9.0, &gamma);
    let mask16: Vec<bool> = (0..16).map(|j| j % 3 != 0).collect();

    bench("rerouter_program_16", budget, || {
        std::hint::black_box(RerouterTree::program(std::hint::black_box(&mask16)));
    });

    let mask64: Vec<bool> = (0..64).map(|j| j % 3 != 0).collect();
    bench("mask_power_64cols", budget, || {
        std::hint::black_box(mask_power_mw(std::hint::black_box(&mask64), 16, &mzi));
    });

    bench("best_segment_mask_16c8_capped", Duration::from_secs(1), || {
        std::hint::black_box(best_segment_mask(16, 8, &mzi, 2_000));
    });

    bench("init_layer_mask_64x576_s0.3", Duration::from_secs(1), || {
        std::hint::black_box(init_layer_mask(1, 9, 64, 64, 16, 0.3, &mzi));
    });
}

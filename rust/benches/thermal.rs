//! Thermal substrate benchmarks: γ(d) evaluation, coupling matrices at
//! several array sizes, and the 2-D heat solve (the Lumerical substitute).

use scatter::bench::timing::{bench, time_once};
use scatter::thermal::heatsim::{solve, HeatSimConfig};
use scatter::thermal::{coupling::ArrayGeometry, CouplingModel, GammaModel};
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(300);
    let gamma = GammaModel::paper();

    bench("gamma_eval_poly_branch", budget, || {
        std::hint::black_box(gamma.eval(std::hint::black_box(9.0)));
    });
    bench("gamma_eval_exp_branch", budget, || {
        std::hint::black_box(gamma.eval(std::hint::black_box(30.0)));
    });

    for (rows, cols) in [(8usize, 8usize), (16, 16), (32, 32)] {
        let geom = ArrayGeometry {
            rows,
            cols,
            l_v: 120.0,
            l_h: 16.0,
            l_s: 9.0,
        };
        bench(&format!("coupling_build_{rows}x{cols}"), budget, || {
            std::hint::black_box(CouplingModel::new(geom, &gamma));
        });
    }

    time_once("heatsim_solve_default_grid", || {
        std::hint::black_box(solve(&HeatSimConfig::default()));
    });
    let fast = HeatSimConfig { dx_um: 1.0, max_iters: 4000, ..Default::default() };
    time_once("heatsim_solve_coarse_grid", || {
        std::hint::black_box(solve(&fast));
    });
}

//! End-to-end table/figure regeneration benchmark: times each paper
//! harness at a reduced sample budget and prints its table. `cargo bench`
//! therefore both exercises and times the full reproduction suite.
//! (Full-budget runs: `cargo run --release -- bench all`.)

use scatter::bench::{self, timing::time_once, BenchCtx};

fn main() {
    let ctx = BenchCtx::new(20); // reduced budget for bench cadence
    let t = time_once("fig4_thermal_characterization", || bench::fig4::run(&ctx));
    println!("{t}");
    let t = time_once("fig5_column_mode_nmae", || bench::fig5::run(&ctx));
    println!("{t}");
    let t = time_once("fig8_eodac_design_points", || bench::fig8::run(&ctx));
    println!("{t}");
    let t = time_once("fig9a_row_patterns", || bench::fig9::run_a(&ctx));
    println!("{t}");
    let t = time_once("fig9b_ig_lr_sweep", || bench::fig9::run_b(&ctx));
    println!("{t}");
    let t = time_once("table1_device_spacing", || bench::table1::run(&ctx));
    println!("{t}");
    let t = time_once("fig6_design_space", || bench::fig6::run(&ctx));
    println!("{t}");
    let t = time_once("table2_sharing_factors", || bench::table2::run(&ctx));
    println!("{t}");
    let t = time_once("fig10_waterfall", || bench::fig10::run(&ctx));
    println!("{t}");
    let t = time_once("table3_main_results_cnn3", || {
        bench::table3::run_models(&ctx, &[bench::common::Workload::Cnn3])
    });
    println!("{t}");
}

//! Coordinator / end-to-end benchmarks: engine matmul throughput (incl.
//! the sparsity-compiled parallel sweep that refreshes
//! `BENCH_engine.json`), whole CNN-3 inference latency on the digital
//! twin, and the AOT artifact execution path (when artifacts exist).

use scatter::bench::timing::{bench, time_once};
use scatter::config::AcceleratorConfig;
use scatter::coordinator::{EngineOptions, PhotonicEngine};
use scatter::data::{DatasetSpec, SyntheticDataset};
use scatter::nn::MatmulEngine;
use scatter::util::XorShiftRng;
use std::time::Duration;

fn main() {
    let cfg = AcceleratorConfig::default();

    // engine matmul: one 64x64 chunk, 64 activation columns per call
    let mut engine = PhotonicEngine::new(cfg.clone(), EngineOptions::NOISY);
    let mut rng = XorShiftRng::new(3);
    let mut w = vec![0.0; 64 * 64];
    rng.fill_uniform(&mut w, -0.5, 0.5);
    let mut x = vec![0.0; 64 * 64];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    // prime the programming cache
    let _ = engine.matmul("bench", &w, &x, 64, 64, 64);
    bench("engine_matmul_64x64x64 (cached prog)", Duration::from_secs(1), || {
        std::hint::black_box(engine.matmul("bench", &w, &x, 64, 64, 64));
    });

    // sparsity-compiled execution sweep: 1/2/4/8 threads ×
    // 0%/50%/87.5% structured column sparsity, reference path included,
    // plus the tall-layer cached-vs-uncached panel sweep and the
    // per-stage breakdown; refreshes BENCH_engine.json at the repo root
    println!(
        "{}",
        scatter::bench::engine::run(&[1, 2, 4, 8], Duration::from_millis(500), true)
    );

    // whole-model inference
    let ds = SyntheticDataset::new(DatasetSpec::fmnist_like());
    let model = scatter::nn::models::cnn3();
    let mut engine = PhotonicEngine::new(cfg, EngineOptions::NOISY);
    let (img, _) = ds.sample(0, 0);
    let _ = model.forward(img.clone(), &mut engine); // program cache warmup
    bench("cnn3_inference_noisy_twin", Duration::from_secs(3), || {
        std::hint::black_box(model.forward(img.clone(), &mut engine));
    });

    // AOT artifact execution, if built
    if let Ok(mut rt) = scatter::runtime::ArtifactRuntime::new("artifacts") {
        if rt.has_artifact("ptc16_noisy") {
            time_once("pjrt_compile_ptc16_noisy", || {
                rt.load("ptc16_noisy").expect("compile artifact");
            });
            let w = vec![0.1f32; 256];
            let g = vec![0.0f32; 256 * 256];
            let m1 = vec![1.0f32; 16];
            let x = vec![0.5f32; 32 * 16];
            let nz = vec![0.0f32; 32 * 16];
            bench("pjrt_execute_ptc16_noisy_b32", Duration::from_secs(2), || {
                std::hint::black_box(
                    rt.run_f32(
                        "ptc16_noisy",
                        &[
                            (&w, &[16, 16]),
                            (&g, &[256, 256]),
                            (&g, &[256, 256]),
                            (&m1, &[16]),
                            (&m1, &[16]),
                            (&x, &[32, 16]),
                            (&nz, &[32, 16]),
                        ],
                    )
                    .expect("execute artifact"),
                );
            });
        } else {
            println!("(artifacts not built; skipping PJRT benches — run `make artifacts`)");
        }
    }
}

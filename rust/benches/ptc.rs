//! Micro-benchmarks for the PTC hot path: coupling-matrix construction,
//! phase perturbation, programming, and the streamed mat-vec (the L3
//! per-cycle cost). §Perf in EXPERIMENTS.md tracks these.

use scatter::bench::timing::bench;
use scatter::devices::DeviceLibrary;
use scatter::ptc::crossbar::{ColumnMode, ForwardOptions, PtcSimulator};
use scatter::thermal::{coupling::ArrayGeometry, CouplingModel, GammaModel};
use scatter::util::XorShiftRng;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(400);
    let gamma = GammaModel::paper();
    let geom = ArrayGeometry { rows: 16, cols: 16, l_v: 120.0, l_h: 16.0, l_s: 9.0 };

    bench("coupling_matrix_build_16x16", budget, || {
        std::hint::black_box(CouplingModel::new(geom, &gamma));
    });

    let cm = CouplingModel::new(geom, &gamma);
    let mut rng = XorShiftRng::new(1);
    let mut phases = vec![0.0; 256];
    rng.fill_uniform(&mut phases, -1.0, 1.0);
    let mut out = vec![0.0; 256];
    bench("perturb_phases_256", budget, || {
        cm.perturb_phases(std::hint::black_box(&phases), &mut out);
        std::hint::black_box(&out);
    });

    let sim = PtcSimulator::new(geom, &gamma, DeviceLibrary::default());
    let mut w = vec![0.0; 256];
    rng.fill_uniform(&mut w, -1.0, 1.0);
    let mut x = vec![0.0; 16];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    let col_mask: Vec<bool> = (0..16).map(|j| j % 2 == 0).collect();
    let opts = ForwardOptions {
        thermal: true,
        pd_noise: true,
        phase_noise: true,
        col_mask: Some(&col_mask),
        col_mode: ColumnMode::InputGatingLr,
        ..Default::default()
    };

    bench("full_forward_16x16 (program+run)", budget, || {
        std::hint::black_box(sim.forward(&w, &x, &opts, &mut rng));
    });

    let mut prog = sim.program(&w, &opts, &mut rng);
    let mut y = vec![0.0; 16];
    bench("programmed_run_16x16 (per cycle)", budget, || {
        y.iter_mut().for_each(|v| *v = 0.0);
        prog.run_into(std::hint::black_box(&x), &mut y, &mut rng);
        std::hint::black_box(&y);
    });

    bench("program_16x16 (per weight update)", budget, || {
        std::hint::black_box(sim.program(&w, &opts, &mut rng));
    });
}
